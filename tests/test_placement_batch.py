"""Batched placement-search engine: delta-kernel exactness, serial parity
(greedy construction AND 2-opt refinement), H-no-worse vs the randomized
serial search, and oracle optimality checks."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.noc import FlattenedButterfly, Mesh2D, Torus2D
from repro.core.partition import powerlaw_partition, random_partition
from repro.core.placement import (
    Placement,
    brute_force_placement,
    greedy_placement,
    greedy_seed,
    ilp_placement,
    move_delta_matrix,
    place,
    quad_placement,
    random_placement,
    swap_delta_matrix,
    symmetrize_weights,
    torus_columnar_placement,
    torus_quad_placement,
    two_opt,
    two_opt_best_move,
)
from repro.core.traffic import traffic_from_partition
from repro.experiments.placement_batch import (
    BATCH_METHOD_SUFFIX,
    batch_descend,
    greedy_construct_batch,
    place_batch,
    torus_construct_batch,
)
from repro.graph.generators import rmat


def _instance(n=12, topo=None, seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n)) * (rng.random((n, n)) < density)
    np.fill_diagonal(w, 0.0)
    return w, topo or Mesh2D(4, 5)


def _paper_configs(n_graphs=4, parts=16, seed=0):
    """Searched paper-grid-shaped configs: real traffic, quad/greedy methods."""
    traffics, partitions, topologies = [], [], []
    for i in range(n_graphs):
        g = rmat(400, 4000, seed=seed + i)
        for part_fn in (powerlaw_partition, random_partition):
            p = part_fn(g.src, g.dst, g.num_nodes, parts)
            traffics.append(traffic_from_partition(p, g.src, g.dst))
            partitions.append(p)
            topologies.append(
                Mesh2D(8, 8) if i % 2 == 0 else FlattenedButterfly(8, 8)
            )
    return traffics, partitions, topologies


class TestDeltaKernels:
    def test_swap_delta_matches_recomputed_h(self):
        w, topo = _instance()
        sym = symmetrize_weights(w)
        d = topo.distance_matrix().astype(np.float64)
        pl = random_placement(12, topo, seed=1)
        h0 = pl.weighted_hops(w)
        ds = swap_delta_matrix(sym, d, pl.site)
        for i, j in ((0, 1), (3, 7), (5, 11), (10, 2)):
            s2 = pl.site.copy()
            s2[i], s2[j] = s2[j], s2[i]
            h1 = Placement(topo, s2, "x").weighted_hops(w)
            assert ds[i, j] == pytest.approx(h1 - h0, abs=1e-9)
        np.testing.assert_allclose(np.diagonal(ds), 0.0)

    def test_move_delta_matches_recomputed_h(self):
        w, topo = _instance(seed=2)
        sym = symmetrize_weights(w)
        d = topo.distance_matrix().astype(np.float64)
        pl = random_placement(12, topo, seed=3)
        h0 = pl.weighted_hops(w)
        dm = move_delta_matrix(sym, d, pl.site)
        occupied = np.zeros(topo.num_nodes, bool)
        occupied[pl.site] = True
        for i in (0, 4, 9):
            for t in np.nonzero(~occupied)[0][:4]:
                s2 = pl.site.copy()
                s2[i] = t
                h1 = Placement(topo, s2, "x").weighted_hops(w)
                assert dm[i, t] == pytest.approx(h1 - h0, abs=1e-9)


class TestBestMoveDescent:
    def test_reaches_full_local_optimum(self):
        w, topo = _instance(seed=4)
        out = two_opt_best_move(random_placement(12, topo, seed=5), w)
        sym = symmetrize_weights(w)
        d = topo.distance_matrix().astype(np.float64)
        ds = swap_delta_matrix(sym, d, out.site)
        np.fill_diagonal(ds, np.inf)
        dm = move_delta_matrix(sym, d, out.site)
        occupied = np.zeros(topo.num_nodes, bool)
        occupied[out.site] = True
        dm[:, occupied] = np.inf
        assert ds.min() >= -1e-9 and dm.min() >= -1e-9

    def test_never_worse_than_init(self):
        for seed in range(5):
            w, topo = _instance(seed=seed)
            pl = random_placement(12, topo, seed=seed)
            out = two_opt_best_move(pl, w)
            assert out.weighted_hops(w) <= pl.weighted_hops(w) + 1e-9

    def test_near_ilp_on_small_instance(self):
        w, _ = _instance(6, topo=Mesh2D(3, 3), seed=3)
        topo = Mesh2D(3, 3)
        ilp = ilp_placement(w, topo, time_limit=30)
        bm = two_opt_best_move(greedy_placement(w, topo), w)
        assert bm.weighted_hops(w) <= 1.3 * ilp.weighted_hops(w) + 1e-9

    def test_matches_brute_force_band_tiny(self):
        w, _ = _instance(4, topo=Mesh2D(2, 2), seed=6, density=0.9)
        topo = Mesh2D(2, 2)
        brute = brute_force_placement(w, topo)
        bm = two_opt_best_move(greedy_placement(w, topo), w)
        assert bm.weighted_hops(w) <= 1.3 * brute.weighted_hops(w) + 1e-9


class TestBatchDescend:
    def test_numpy_bit_identical_to_serial_reference(self):
        """Acceptance parity: the stacked numpy recursion applies exactly the
        moves `two_opt_best_move` applies, config by config."""
        traffics, _, topologies = _paper_configs(3)
        ws = [t.bytes_matrix for t in traffics]
        inits = [quad_placement(16, topo).site for topo in topologies]
        out, stats = batch_descend(ws, topologies, inits, backend="numpy")
        assert stats.backend == "numpy" and stats.batched_configs == len(ws)
        for w, topo, init, sites in zip(ws, topologies, inits, out):
            ref = two_opt_best_move(Placement(topo, init, "quad"), w)
            np.testing.assert_array_equal(sites, ref.site)

    def test_jax_backend_matches_numpy_h(self):
        pytest.importorskip("jax")
        traffics, _, topologies = _paper_configs(2)
        ws = [t.bytes_matrix for t in traffics]
        inits = [quad_placement(16, topo).site for topo in topologies]
        out_np, _ = batch_descend(ws, topologies, inits, backend="numpy")
        out_jx, stats = batch_descend(ws, topologies, inits, backend="jax")
        assert stats.backend == "jax"
        for w, topo, s_np, s_jx in zip(ws, topologies, out_np, out_jx):
            h_np = Placement(topo, s_np, "x").weighted_hops(w)
            h_jx = Placement(topo, np.asarray(s_jx), "x").weighted_hops(w)
            # f32 tie-breaking may take a different descent path; the
            # converged quality must match to f32 tolerance.
            assert h_jx == pytest.approx(h_np, rel=1e-3)

    def test_mixed_topologies_share_one_group(self):
        """mesh2d and fbutterfly of equal size stack into one program and
        still get their own distance metric."""
        w, _ = _instance(8, topo=Mesh2D(4, 4), seed=7, density=0.8)
        topos = [Mesh2D(4, 4), FlattenedButterfly(4, 4), Torus2D(4, 4)]
        init = random_placement(8, topos[0], seed=8).site
        out, stats = batch_descend([w] * 3, topos, [init] * 3, backend="numpy")
        assert stats.groups == 1
        for topo, sites in zip(topos, out):
            ref = two_opt_best_move(Placement(topo, init, "r"), w)
            np.testing.assert_array_equal(sites, ref.site)


def _random_weight_stack(seed: int, n: int, c: int, density: float = 0.5):
    """C raw (possibly asymmetric) weight matrices with occasional
    zero-connectivity shards (exercising the greedy rng fallback)."""
    rng = np.random.default_rng(seed)
    ws = []
    for _ in range(c):
        w = rng.random((n, n)) * (rng.random((n, n)) < density)
        np.fill_diagonal(w, 0.0)
        for i in rng.integers(n, size=rng.integers(0, 3)):
            w[:, i] = 0.0
            w[i, :] = 0.0
        ws.append(w)
    return ws


class TestGreedyConstructBatch:
    def test_numpy_bit_identical_to_serial_greedy_on_real_traffic(self):
        """Tentpole parity: the stacked argmax-insertion equals
        `greedy_placement` config by config on paper-shaped traffic."""
        traffics, _, topologies = _paper_configs(3)
        ws = [t.bytes_matrix for t in traffics]
        seeds = list(range(len(ws)))
        sites, backend = greedy_construct_batch(ws, topologies, seeds=seeds, backend="numpy")
        assert backend == "numpy"
        for w, topo, s, out in zip(ws, topologies, seeds, sites):
            ref = greedy_placement(w, topo, seed=s)
            np.testing.assert_array_equal(out, ref.site)

    def test_rng_fallback_path_matches_serial(self):
        """Zero-connectivity shards hit the seeded-random fallback; the
        batched numpy path must replay the identical per-config rng stream."""
        ws = _random_weight_stack(seed=11, n=20, c=6, density=0.25)
        topos = [Mesh2D(4, 6), Torus2D(4, 6), FlattenedButterfly(4, 6)] * 2
        sites, _ = greedy_construct_batch(ws, topos, seeds=7, backend="numpy")
        for w, topo, out in zip(ws, topos, sites):
            ref = greedy_placement(w, topo, seed=7)
            np.testing.assert_array_equal(out, ref.site)

    def test_mixed_topologies_keep_their_own_metric(self):
        """A torus config in the stack must see wraparound distances, not its
        mesh neighbours'."""
        (w,) = _random_weight_stack(seed=2, n=12, c=1, density=0.8)
        topos = [Mesh2D(4, 4), Torus2D(4, 4)]
        sites, _ = greedy_construct_batch([w, w], topos, seeds=0, backend="numpy")
        for topo, out in zip(topos, sites):
            np.testing.assert_array_equal(out, greedy_placement(w, topo, seed=0).site)

    def test_seed_rule_shared_with_serial(self):
        (w,) = _random_weight_stack(seed=4, n=10, c=1, density=0.9)
        topo = Mesh2D(4, 4)
        w2 = w + w.T
        first, center = greedy_seed(w2, topo.distance_matrix().astype(np.float64))
        assert first == int(w2.sum(1).argmax())
        (site_arr,), _ = greedy_construct_batch([w], [topo], seeds=0, backend="numpy")
        assert site_arr[first] == center

    def test_jax_backend_valid_and_h_close_after_refinement(self):
        """f32 argmax near-ties give the jax constructor a different (equally
        legitimate) insertion order, so raw layouts aren't bit-equal; the
        contract is a valid injective layout whose *refined* H matches the
        numpy path's to a few percent (the basins are the same)."""
        pytest.importorskip("jax")
        traffics, _, topologies = _paper_configs(2)
        ws = [t.bytes_matrix for t in traffics]
        s_np, _ = greedy_construct_batch(ws, topologies, seeds=0, backend="numpy")
        s_jx, backend = greedy_construct_batch(ws, topologies, seeds=0, backend="jax")
        assert backend == "jax"
        r_np, _ = batch_descend(ws, topologies, s_np, backend="numpy")
        r_jx, _ = batch_descend(
            ws, topologies, [np.asarray(s) for s in s_jx], backend="numpy"
        )
        for w, topo, raw, a, b in zip(ws, topologies, s_jx, r_np, r_jx):
            assert np.unique(raw).size == len(raw)  # injective layout
            h_np = Placement(topo, a, "x").weighted_hops(w)
            h_jx = Placement(topo, b, "x").weighted_hops(w)
            assert h_jx <= 1.05 * h_np + 1e-9

    def test_results_are_valid_injective_site_arrays(self):
        ws = _random_weight_stack(seed=9, n=16, c=4, density=0.4)
        sites, _ = greedy_construct_batch(ws, [Mesh2D(4, 5)] * 4, seeds=1, backend="numpy")
        for out in sites:
            assert np.unique(out).size == out.size
            assert out.min() >= 0 and out.max() < 20

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_parity_property(self, seed):
        """Property form of the bit-parity contract: any weight stack, any
        equal-shape topology mix, any seed — batched == serial, exactly.
        (Skips without hypothesis; the deterministic tests above keep the
        same contract pinned.)"""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 18))
        c = int(rng.integers(1, 5))
        kx, ky = 4, (n + 3) // 4 + 1
        topo_pool = [Mesh2D(kx, ky), Torus2D(kx, ky), FlattenedButterfly(kx, ky)]
        topos = [topo_pool[int(rng.integers(3))] for _ in range(c)]
        ws = _random_weight_stack(int(seed) + 1, n, c, density=float(rng.uniform(0.1, 1.0)))
        sites, _ = greedy_construct_batch(ws, topos, seeds=int(seed) % 17, backend="numpy")
        for w, topo, out in zip(ws, topos, sites):
            ref = greedy_placement(w, topo, seed=int(seed) % 17)
            np.testing.assert_array_equal(out, ref.site)


def _torus_configs(n_graphs=3, parts=16):
    traffics, partitions, topologies = [], [], []
    for i in range(n_graphs):
        g = rmat(300, 2500, seed=i)
        for part_fn in (powerlaw_partition, random_partition):
            p = part_fn(g.src, g.dst, g.num_nodes, parts)
            traffics.append(traffic_from_partition(p, g.src, g.dst))
            partitions.append(p)
            topologies.append(Torus2D(8, 8))
    return traffics, partitions, topologies


class TestTorusConstructBatch:
    def test_numpy_bit_identical_to_serial_on_real_traffic(self):
        """Tentpole parity: the stacked torus layout assembly equals the
        serial constructors config by config (same contract as the greedy
        constructor)."""
        traffics, _, topologies = _torus_configs()
        ws = [t.bytes_matrix for t in traffics]
        sites, backend = torus_construct_batch(ws, topologies, backend="numpy")
        assert backend == "numpy"
        for w, topo, out in zip(ws, topologies, sites):
            np.testing.assert_array_equal(out, torus_quad_placement(16, topo, w).site)
        sites_c, _ = torus_construct_batch(
            ws, topologies, methods="torus_columnar", backend="numpy"
        )
        for w, topo, out in zip(ws, topologies, sites_c):
            np.testing.assert_array_equal(out, torus_columnar_placement(16, topo, w).site)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_batched_vs_serial_bit_exactness_property(self, seed):
        """Property form: any weight stack, any torus size, any method mix —
        batched == serial, exactly, on the numpy backend."""
        rng = np.random.default_rng(seed)
        parts = int(rng.integers(2, 9))
        kx = int(rng.integers(2, 5)) * 2
        # tall enough that 2x2 quads always fit; columnar fits when ky >= 4P/kx
        ky = max(4, 2 * (-(-parts // (kx // 2))) + 2 * int(rng.integers(0, 2)))
        topo = Torus2D(kx, ky)
        c = int(rng.integers(1, 4))
        ws = _random_weight_stack(seed + 1, 4 * parts, c, density=float(rng.uniform(0.2, 1.0)))
        methods = []
        for _ in range(c):
            quad_ok = (kx // 2) * (ky // 2) >= parts
            col_ok = parts <= kx * (ky // 4)
            opts = (["torus_quad"] if quad_ok else []) + (["torus_columnar"] if col_ok else [])
            methods.append(opts[int(rng.integers(len(opts)))])
        sites, _ = torus_construct_batch(ws, [topo] * c, methods=methods, backend="numpy")
        serial = {"torus_quad": torus_quad_placement, "torus_columnar": torus_columnar_placement}
        for w, m, out in zip(ws, methods, sites):
            np.testing.assert_array_equal(out, serial[m](parts, topo, w).site)

    def test_jax_backend_valid_and_h_matches_numpy(self):
        pytest.importorskip("jax")
        traffics, _, topologies = _torus_configs(2)
        ws = [t.bytes_matrix for t in traffics]
        s_np, _ = torus_construct_batch(ws, topologies, backend="numpy")
        s_jx, backend = torus_construct_batch(ws, topologies, backend="jax")
        assert backend == "jax"
        for w, topo, a, b in zip(ws, topologies, s_np, s_jx):
            assert np.unique(b).size == len(b)  # injective layout
            h_np = Placement(topo, a, "x").weighted_hops(w)
            h_jx = Placement(topo, np.asarray(b), "x").weighted_hops(w)
            # f32 near-ties may reorder equal-weight hub parts; converged
            # quality must match to f32 tolerance.
            assert h_jx == pytest.approx(h_np, rel=1e-3)

    def test_place_batch_routes_auto_torus_to_stacked_construction(self):
        """Acceptance: torus2d "auto" configs are torus-constructed (no
        descent), carry the constructive method tag, match the serial
        `place` path exactly, and record the construct/search time split."""
        traffics, partitions, topologies = _torus_configs()
        pls, stats = place_batch(
            traffics, partitions, topologies, methods="auto", seeds=0, backend="numpy"
        )
        assert stats.torus_constructed == len(traffics)
        assert stats.batched_configs == 0 and stats.serial_configs == 0
        assert stats.steps == 0  # no descent ran
        assert stats.construct_s > 0 and stats.search_s == 0
        for t, p, topo, pl in zip(traffics, partitions, topologies, pls):
            assert pl.method == "torus_quad"
            serial = place(t, p, topo, method="auto", seed=0)
            np.testing.assert_array_equal(pl.site, serial.site)

    def test_mixed_torus_and_mesh_grid_splits_between_engines(self):
        """A torus-grid-shaped mix: mesh2d configs descend, torus2d configs
        construct — and the constructive H beats the searched H on the same
        traffic (the §Torus acceptance)."""
        traffics, partitions, _ = _torus_configs(2)
        topologies = [Mesh2D(8, 8), Torus2D(8, 8)] * 2
        pls, stats = place_batch(
            traffics, partitions, topologies, methods="auto", seeds=0, backend="numpy"
        )
        assert stats.torus_constructed == 2 and stats.batched_configs == 2
        greedy_pls, _ = place_batch(
            traffics, partitions, topologies, methods="greedy", seeds=0, backend="numpy"
        )
        for t, topo, pl, searched in zip(traffics, topologies, pls, greedy_pls):
            if isinstance(topo, Torus2D):
                assert pl.method == "torus_quad"
                assert pl.weighted_hops(t.bytes_matrix) <= searched.weighted_hops(
                    t.bytes_matrix
                ) + 1e-9


class TestPlaceBatch:
    def test_h_no_worse_than_serial_place_at_matched_budgets(self):
        """Acceptance: batched H ≤ serial greedy/quad+two_opt H per config."""
        traffics, partitions, topologies = _paper_configs(4)
        pls, stats = place_batch(
            traffics, partitions, topologies, methods="auto", seeds=0, backend="numpy"
        )
        assert stats.batched_configs == len(traffics)
        for t, p, topo, pl in zip(traffics, partitions, topologies, pls):
            serial = place(t, p, topo, method="auto", seed=0)
            assert pl.weighted_hops(t.bytes_matrix) <= serial.weighted_hops(
                t.bytes_matrix
            ) + 1e-9
            assert pl.method.endswith(BATCH_METHOD_SUFFIX)

    def test_pinned_greedy_uses_stacked_construction_no_serial_loop(self):
        """Acceptance: a grid pinning placement=greedy routes every config
        through the batched constructor (greedy_constructed == searched) and
        stays H-no-worse than the serial greedy+two_opt path."""
        traffics, partitions, topologies = _paper_configs(3)
        pls, stats = place_batch(
            traffics, partitions, topologies, methods="greedy", seeds=0, backend="numpy"
        )
        assert stats.batched_configs == len(traffics)
        assert stats.greedy_constructed == len(traffics)
        assert stats.serial_configs == 0
        for t, p, topo, pl in zip(traffics, partitions, topologies, pls):
            serial = place(t, p, topo, method="greedy", seed=0)
            assert pl.weighted_hops(t.bytes_matrix) <= serial.weighted_hops(
                t.bytes_matrix
            ) + 1e-9
            assert pl.method == "greedy" + BATCH_METHOD_SUFFIX

    def test_restarts_never_hurt(self):
        traffics, partitions, topologies = _paper_configs(2)
        base, _ = place_batch(
            traffics, partitions, topologies, methods="auto", seeds=0, backend="numpy"
        )
        kicked, stats = place_batch(
            traffics,
            partitions,
            topologies,
            methods="auto",
            seeds=0,
            restarts=2,
            backend="numpy",
        )
        assert stats.restarts == 2
        for t, b, k in zip(traffics, base, kicked):
            assert k.weighted_hops(t.bytes_matrix) <= b.weighted_hops(t.bytes_matrix) + 1e-9

    def test_constructive_methods_fall_through_to_serial(self):
        traffics, partitions, topologies = _paper_configs(1)
        pls, stats = place_batch(
            traffics[:2],
            partitions[:2],
            topologies[:2],
            methods=["random", "columnar"],
            seeds=5,
        )
        assert stats.serial_configs == 2 and stats.batched_configs == 0
        serial = place(traffics[0], partitions[0], topologies[0], method="random", seed=5)
        np.testing.assert_array_equal(pls[0].site, serial.site)

    def test_results_are_valid_injective_placements(self):
        traffics, partitions, topologies = _paper_configs(2)
        pls, _ = place_batch(
            traffics, partitions, topologies, methods="auto", seeds=0, backend="numpy"
        )
        for pl in pls:
            assert np.unique(pl.site).size == pl.site.size  # Placement re-checks too

    def test_deterministic_across_calls(self):
        traffics, partitions, topologies = _paper_configs(1)
        a, _ = place_batch(traffics, partitions, topologies, methods="auto", seeds=3)
        b, _ = place_batch(traffics, partitions, topologies, methods="auto", seeds=3)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x.site, y.site)

    def test_small_instance_tracks_ilp_oracle(self):
        """On an exactly-solvable instance the batched search lands within
        the same 1.3× band the serial search is held to."""
        g = rmat(80, 600, seed=9)
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, 2)
        t = traffic_from_partition(p, g.src, g.dst)
        topo = Mesh2D(3, 3)
        ilp = ilp_placement(t.bytes_matrix, topo, time_limit=30)
        pls, _ = place_batch([t], [p], [topo], methods="greedy", seeds=0, backend="numpy")
        h_b = pls[0].weighted_hops(t.bytes_matrix)
        assert h_b <= 1.3 * ilp.weighted_hops(t.bytes_matrix) + 1e-9
