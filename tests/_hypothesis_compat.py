"""Optional-hypothesis shim: `from _hypothesis_compat import given, settings, st`.

When hypothesis is installed (the `[test]` extra, see pyproject.toml) the real
decorators are re-exported unchanged.  When it is absent — the offline CI
container has no wheel — a minimal VENDORED fallback runner takes over
instead of skipping: each `@given` test runs `settings(max_examples=…)`
deterministic pseudo-random examples (seeded from the test's qualname, so
failures reproduce across runs and machines).  The fallback implements just
the strategy surface this suite uses (`integers`, `booleans`, `floats`,
`sampled_from`, `tuples`); anything fancier should go through real
hypothesis.  No shrinking — the failing example is reported as-is.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 25
    _SETTINGS_ATTR = "_fallback_max_examples"

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

    class _Strategies:
        """The subset of hypothesis.strategies the fallback runner supports."""

        @staticmethod
        def integers(min_value=0, max_value=None):
            lo = 0 if min_value is None else int(min_value)
            hi = lo + 2**16 if max_value is None else int(max_value)
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

        def __getattr__(self, name):  # anything else: fail loudly, not subtly
            raise NotImplementedError(
                f"strategies.{name} is not implemented by the vendored "
                "hypothesis fallback (tests/_hypothesis_compat.py); "
                "pip install hypothesis or extend the fallback"
            )

    st = _Strategies()

    def settings(*_args, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        def deco(fn):
            setattr(fn, _SETTINGS_ATTR, max_examples)
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            def runner(*args, **kwargs):
                n = getattr(
                    runner, _SETTINGS_ATTR, getattr(fn, _SETTINGS_ATTR, _DEFAULT_MAX_EXAMPLES)
                )
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(base * 1_000_003 + i)
                    drawn = [s.draw(rng) for s in arg_strategies]
                    drawn_kw = {k: s.draw(rng) for k, s in sorted(kw_strategies.items())}
                    try:
                        fn(*args, *drawn, **drawn_kw, **kwargs)
                    except Exception as e:
                        example = drawn or drawn_kw
                        raise AssertionError(
                            f"[vendored-hypothesis fallback] falsifying example "
                            f"#{i + 1}/{n} of {fn.__qualname__}: {example!r}"
                        ) from e

            # The (*args, **kwargs) signature is deliberate: pytest must not
            # resolve the wrapped function's own argument names as fixtures.
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
