"""Optional-hypothesis shim: `from _hypothesis_compat import given, settings, st`.

When hypothesis is installed (the `[test]` extra, see pyproject.toml) the real
decorators are re-exported unchanged.  When it is absent the property tests
skip individually at run time instead of killing collection for the whole
file, so the plain unit tests in the same module still run.
"""
from __future__ import annotations

import functools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (pip install hypothesis)")

            # functools.wraps copies __wrapped__, which would make pytest
            # resolve the original argument names as fixtures; drop it so the
            # (*args, **kwargs) signature (no fixture requests) is seen.
            del skipper.__wrapped__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        """Placeholder for `strategies`: any attribute is a callable stub."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None

            strategy.__name__ = name
            return strategy

    st = _AnyStrategy()
