"""Paper core: Algorithm 2 partitioning — unit + hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.degree import fit_power_law, hub_set, out_degrees, skew_stats
from repro.core.partition import (
    hash_partition,
    partition_by_name,
    powerlaw_partition,
    random_partition,
    range_partition,
)
from repro.graph.generators import chung_lu, rmat


def edges(n, e, seed=0):
    g = rmat(n, e, seed=seed)
    return g.src, g.dst, g.num_nodes


class TestPowerlawPartition:
    def test_all_assigned(self):
        src, dst, n = edges(200, 1600)
        p = powerlaw_partition(src, dst, n, 8)
        assert p.vertex_part.shape == (n,)
        assert ((0 <= p.vertex_part) & (p.vertex_part < 8)).all()
        assert ((0 <= p.edge_part) & (p.edge_part < 8)).all()

    def test_source_cut(self):
        """Each edge lives with its source vertex's engine (pre-spill)."""
        src, dst, n = edges(200, 1600)
        p = powerlaw_partition(src, dst, n, 8, max_size=10**9)
        np.testing.assert_array_equal(p.edge_part, p.vertex_part[src])

    def test_cyclic_deal_over_degree_sort(self):
        """Vertices at sorted positions i, i+P land on consecutive engines."""
        src, dst, n = edges(200, 1600)
        p = powerlaw_partition(src, dst, n, 4)
        pos_part = p.vertex_part[p.order]  # partition in degree-sorted order
        np.testing.assert_array_equal(pos_part, np.arange(n) % 4)

    def test_better_balance_than_range(self):
        src, dst, n = edges(500, 8000, seed=1)
        bal_pl = powerlaw_partition(src, dst, n, 16).edge_balance()
        bal_rg = range_partition(src, dst, n, 16).edge_balance()
        assert bal_pl <= bal_rg  # the paper's load-balancing claim

    def test_capacity_spill(self):
        src, dst, n = edges(100, 2000, seed=2)
        cap = 2000 // 4 + 60
        p = powerlaw_partition(src, dst, n, 4, max_size=cap)
        assert p.edge_counts().max() <= cap

    def test_capacity_too_small_raises(self):
        src, dst, n = edges(100, 2000, seed=2)
        with pytest.raises(ValueError):
            powerlaw_partition(src, dst, n, 4, max_size=100)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(10, 120),
        parts=st.integers(2, 8),
        seed=st.integers(0, 10_000),
    )
    def test_property_invariants(self, n, parts, seed):
        rng = np.random.default_rng(seed)
        e = max(n, 2 * n)
        src = rng.integers(0, n, e)
        dst = rng.integers(0, n, e)
        for name in ("powerlaw", "random", "range", "hash"):
            p = partition_by_name(name, src, dst, n, parts)
            # every vertex/edge on a valid engine; counts sum to totals
            assert p.vertex_counts().sum() == n
            assert p.edge_counts().sum() == e
            # rank is a valid sorted-position
            assert ((0 <= p.rank) & (p.rank < max(n, 1))).all()

    @settings(max_examples=15, deadline=None)
    @given(parts=st.integers(2, 16), seed=st.integers(0, 100))
    def test_powerlaw_balance_bound(self, parts, seed):
        """Cyclic deal over the degree sort keeps edge imbalance ≤ the
        heaviest hub share + 1/P of the remainder (loose 2× bound here)."""
        g = rmat(256, 4096, seed=seed)
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, parts)
        assert p.edge_balance() <= 2.0


class TestDegreeStats:
    def test_powerlaw_fit_positive_alpha(self):
        g = rmat(2000, 30_000, seed=0)
        alpha = fit_power_law(out_degrees(g.src, g.num_nodes))
        assert alpha > 0.5

    def test_skew_matches_paper_fig4(self):
        """≤35% of vertices cover ≥90% of edges on an RMAT graph (Fig. 4's
        skew; real SNAP graphs are even more skewed)."""
        g = rmat(5000, 100_000, seed=1)
        stats = skew_stats(out_degrees(g.src, g.num_nodes))
        assert stats.frac_vertices_for_90pct_edges <= 0.35

    def test_hub_set_small(self):
        g = rmat(1000, 20_000, seed=2)
        hubs = hub_set(out_degrees(g.src, g.num_nodes), edge_coverage=0.5)
        assert hubs.size <= 0.05 * g.num_nodes + 1

    def test_uniform_graph_not_powerlaw(self):
        from repro.graph.generators import uniform_random

        g = uniform_random(2000, 20_000, seed=0)
        stats = skew_stats(out_degrees(g.src, g.num_nodes))
        assert stats.frac_vertices_for_90pct_edges > 0.4
