"""Memory-budget guard for the sparse-first pipeline at published scale.

Runs the `scale` grid's soc-pokec config at scale 0.1 (160k vertices, 3.06M
edges) and asserts the process peak RSS stays under budget.  Measured peak on
the reference container is ~1.03 GiB, dominated by R-MAT generation
transients; the 2 GiB budget leaves ~2× headroom while still failing fast if
a refactor reintroduces an O(|E|)-per-stage dense materialization (the
pre-sparse pipeline could not run this config at all).

Gated twice so tier-1 stays fast: the `slow` marker, and the
REPRO_SCALE_RSS=1 env var set by scripts/verify.sh.
"""
import dataclasses
import os

import pytest

PEAK_RSS_BUDGET_MB = 2048

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(
        os.environ.get("REPRO_SCALE_RSS") != "1",
        reason="set REPRO_SCALE_RSS=1 (scripts/verify.sh does) to run the RSS guard",
    ),
]


def test_scale_0p1_peak_rss_under_budget():
    from repro.experiments.grid import GRIDS
    from repro.experiments.sweep import peak_rss_mb, run_sweep

    grid = dataclasses.replace(GRIDS["scale"], scales=(0.1,))
    result = run_sweep(grid, cache_dir=None)
    assert len(result.records) == 2  # proposed vs baseline schemes
    for rec in result.records:
        assert rec.num_edges >= 3_000_000
    assert result.memory["final_mb"] > 0
    peak = peak_rss_mb()
    assert peak < PEAK_RSS_BUDGET_MB, (
        f"scale-0.1 sweep peaked at {peak:.0f} MiB (budget {PEAK_RSS_BUDGET_MB})"
    )
