"""Dense-parity property harness for the sparse-first pipeline.

The contract under test (see `repro.core.traffic` module docstring): traffic
bytes are integer-valued float64 (iteration counts × packet bytes) and hop
distances are integers, so every sparse/blocked/chunked re-association of the
dense reference computation is BIT-IDENTICAL — equality below is
`np.array_equal` / `==`, not allclose, except where a jax f32 backend is
explicitly in play (tolerances stated inline).

Covered, per random graph × all four topologies × both traffic models:
  * traffic matrices: dense single-pass vs sparse/blocked/auto layouts,
    every edge-block size, plus the `SweepCache` shard path;
  * H evaluation: `sparse_weighted_hops` (+ the batched numpy/jax versions)
    vs the dense `Placement.weighted_hops`;
  * per-step swap/move deltas: `swap_delta_pairs` vs the dense
    `swap_delta_matrix`, blocked `two_opt_best_move` descent vs dense,
    `two_opt_topk(k=n)` replaying the dense search exactly;
  * chunked windows: `simulate_batch(pair_block=...)` and
    `contended_batch(window_chunk=...)` vs their unchunked runs, on both
    backends, for arbitrary chunk sizes.
"""
from _hypothesis_compat import given, settings, st

import numpy as np
import pytest

from repro.core.noc import FlattenedButterfly, Mesh2D, Torus2D, Torus3D
from repro.core.partition import powerlaw_partition
from repro.core.placement import (
    default_max_steps,
    random_placement,
    sparse_weighted_hops,
    swap_candidates_topk,
    swap_delta_matrix,
    swap_delta_pairs,
    two_opt_best_move,
    two_opt_topk,
)
from repro.core.traffic import SparseTraffic, TrafficMatrix, traffic_from_partition
from repro.experiments.batched import simulate_batch
from repro.experiments.placement_batch import (
    batch_descend,
    sparse_weighted_hops_batch,
    swap_delta_pairs_batch,
)
from repro.graph.generators import rmat
from repro.nocsim.batch import contended_batch

# One topology per family, sized for 4P logical shards at small P.
TOPOLOGIES = {
    "mesh2d": lambda: Mesh2D(4, 4),
    "fbutterfly": lambda: FlattenedButterfly(4, 4),
    "torus2d": lambda: Torus2D(4, 4),
    "torus3d": lambda: Torus3D(2, 3, 6),
}


def _graph_and_partition(seed: int, num_parts: int = 4):
    g = rmat(200, 1600, seed=seed)
    part = powerlaw_partition(g.src, g.dst, g.num_nodes, num_parts)
    return g, part


def _activities(g, seed: int):
    rng = np.random.default_rng(seed)
    ea = rng.integers(0, 6, size=g.src.size).astype(np.float64)
    va = rng.integers(0, 8, size=g.num_nodes).astype(np.float64)
    return ea, va


class TestTrafficParity:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        model=st.sampled_from(["paper", "cross"]),
        edge_block=st.sampled_from([1, 3, 17, 100, 10**6, None]),
        with_activity=st.booleans(),
    )
    def test_sparse_blocked_bitexact_vs_dense(self, seed, model, edge_block, with_activity):
        g, part = _graph_and_partition(seed)
        ea, va = _activities(g, seed) if with_activity else (None, None)
        dense = traffic_from_partition(
            part, g.src, g.dst, edge_activity=ea, vertex_activity=va, model=model
        )
        sp = traffic_from_partition(
            part, g.src, g.dst, edge_activity=ea, vertex_activity=va,
            model=model, layout="sparse", edge_block=edge_block,
        )
        assert isinstance(sp, SparseTraffic)
        assert np.array_equal(sp.to_dense().bytes_matrix, dense.bytes_matrix)
        assert sp.phase_bytes == dense.phase_bytes
        # canonical COO: identical triplets to np.nonzero of the dense matrix
        ref = dense.to_sparse()
        assert np.array_equal(sp.rows, ref.rows)
        assert np.array_equal(sp.cols, ref.cols)
        assert np.array_equal(sp.vals, ref.vals)
        # blocked dense layout is the same accumulation, materialized
        d2 = traffic_from_partition(
            part, g.src, g.dst, edge_activity=ea, vertex_activity=va,
            model=model, layout="dense", edge_block=edge_block,
        )
        assert isinstance(d2, TrafficMatrix)
        assert np.array_equal(d2.bytes_matrix, dense.bytes_matrix)

    def test_auto_layout_hatch(self):
        g, part = _graph_and_partition(0)
        t = traffic_from_partition(part, g.src, g.dst, layout="auto")
        assert isinstance(t, TrafficMatrix)  # 16 logical shards ≤ hatch

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_symmetrized_coo_matches_dense(self, seed):
        g, part = _graph_and_partition(seed)
        sp = traffic_from_partition(part, g.src, g.dst, layout="sparse")
        rows, cols, vals = sp.symmetrized_coo()
        n = sp.num_logical
        m = np.zeros((n, n))
        m[rows, cols] = vals
        assert np.array_equal(m, sp.to_dense().symmetrized())


class TestPlacementKernelParity:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 1000), topo=st.sampled_from(sorted(TOPOLOGIES)))
    def test_sparse_h_bitexact(self, seed, topo):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        pl = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        rows, cols = np.nonzero(w)
        h_sparse = sparse_weighted_hops(
            rows, cols, w[rows, cols], topology.distance_matrix(), pl.site
        )
        assert h_sparse == pl.weighted_hops(w)

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 1000), topo=st.sampled_from(sorted(TOPOLOGIES)))
    def test_pair_deltas_bitexact_vs_dense_matrix(self, seed, topo):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        pl = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        d = topology.distance_matrix()
        site = pl.site
        dense = swap_delta_matrix(w, d, site)
        n = w.shape[0]
        iu, ju = np.triu_indices(n, k=1)
        got = swap_delta_pairs(w, d, site, iu, ju)
        assert np.array_equal(got, dense[iu, ju])

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        topo=st.sampled_from(["mesh2d", "torus2d", "fbutterfly"]),
        block=st.sampled_from([1, 5, 17, 1000]),
    )
    def test_blocked_descent_bitidentical(self, seed, topo, block):
        """Streaming the per-step swap/move argmin over row blocks reproduces
        the dense descent step-for-step (strict-< streaming update == argmin
        first-occurrence tie-break)."""
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        init = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        ref = two_opt_best_move(init, w)
        got = two_opt_best_move(init, w, swap_block=block)
        assert np.array_equal(got.site, ref.site)
        assert got.weighted_hops(w) == ref.weighted_hops(w)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_topk_full_k_replays_dense_search(self, seed):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = Mesh2D(4, 4)
        init = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        ref = two_opt_best_move(init, w)
        got = two_opt_topk(init, w, k=t.num_logical)
        assert np.array_equal(got.site, ref.site)

    def test_topk_candidates_cover_dense_at_full_k(self):
        g, part = _graph_and_partition(3)
        t = traffic_from_partition(part, g.src, g.dst)
        w = t.symmetrized()
        rows, cols = np.nonzero(w)
        n = t.num_logical
        pi, pj = swap_candidates_topk(rows, cols, w[rows, cols], n, n)
        assert np.all(pi < pj)
        # k=n makes every shard a hub, so the candidate set is all pairs
        assert pi.size == n * (n - 1) // 2
        restricted = swap_candidates_topk(rows, cols, w[rows, cols], n, 2)
        assert restricted[0].size < pi.size

    @settings(max_examples=6, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        topo=st.sampled_from(sorted(TOPOLOGIES)),
        block=st.sampled_from([1, 5, 13, 100]),
    )
    def test_batched_blocked_descent_bitidentical(self, seed, topo, block):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        init = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        steps = default_max_steps(t.num_logical)
        ref, _ = batch_descend([w], [topology], [init.site],
                               max_steps=steps, backend="numpy")
        got, _ = batch_descend([w], [topology], [init.site],
                               max_steps=steps, backend="numpy", swap_block=block)
        assert np.array_equal(got[0], ref[0])

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1000), topo=st.sampled_from(sorted(TOPOLOGIES)))
    def test_sparse_h_batch_both_backends(self, seed, topo):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        pl = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        rows, cols = np.nonzero(w)
        coo = (rows, cols, w[rows, cols])
        sites = [pl.site]
        ref = pl.weighted_hops(w)
        h_np, b = sparse_weighted_hops_batch([coo], sites, [topology], backend="numpy")
        assert b == "numpy" and h_np[0] == ref
        h_jx, b = sparse_weighted_hops_batch([coo], sites, [topology], backend="jax")
        if b == "jax":  # container has jax; f32 max-normalized contraction
            assert abs(h_jx[0] - ref) / max(abs(ref), 1e-300) < 1e-5

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1000), topo=st.sampled_from(sorted(TOPOLOGIES)))
    def test_pair_deltas_batch_both_backends(self, seed, topo):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = TOPOLOGIES[topo]()
        pl = random_placement(t.num_logical, topology, seed=seed)
        w = t.symmetrized()
        d = topology.distance_matrix()
        site = pl.site
        n = w.shape[0]
        iu, ju = np.triu_indices(n, k=1)
        ref = swap_delta_matrix(w, d, site)[iu, ju]
        # the batch kernel takes RAW weights and symmetrizes internally
        raw = t.bytes_matrix
        got_np, b = swap_delta_pairs_batch([raw], [topology], [site], [(iu, ju)],
                                           backend="numpy")
        assert b == "numpy" and np.array_equal(got_np[0], ref)
        got_jx, b = swap_delta_pairs_batch([raw], [topology], [site], [(iu, ju)],
                                           backend="jax")
        if b == "jax":
            scale = max(np.abs(ref).max(), 1.0)
            assert np.max(np.abs(got_jx[0] - ref)) / scale < 1e-4


class TestChunkedWindows:
    def _configs(self, seed):
        g, part = _graph_and_partition(seed)
        t = traffic_from_partition(part, g.src, g.dst)
        topology = Mesh2D(4, 4)
        pl = random_placement(t.num_logical, topology, seed=seed)
        return t, pl

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 1000),
        block=st.sampled_from([1, 17, 300, 10**7]),
        sparse_input=st.booleans(),
    )
    def test_simulate_batch_pair_block_bitexact(self, seed, block, sparse_input):
        t, pl = self._configs(seed)
        traffic = t.to_sparse() if sparse_input else t
        ref = simulate_batch([t], [pl], backend="numpy")[0]
        got = simulate_batch([traffic], [pl], backend="numpy", pair_block=block)[0]
        for f in ("exec_time_s", "energy_j", "avg_hops", "byte_hops", "total_bytes"):
            assert getattr(got, f) == getattr(ref, f), f

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([1, 3, 7, 64, 1000]))
    def test_contended_window_chunks_bitexact_both_backends(self, seed, chunk):
        t, pl = self._configs(seed)
        for backend in ("numpy", "jax"):
            try:
                ref = contended_batch([t], [pl], backend=backend)[0]
            except Exception:
                if backend == "jax":
                    pytest.skip("jax unavailable")
                raise
            got = contended_batch([t], [pl], backend=backend, window_chunk=chunk)[0]
            # The chunked recursion resumes from the carried backlog, which is
            # exactly the unchunked state at the boundary — bit-identical even
            # on the f32 jax backend (f32→f64→f32 carry round-trips losslessly).
            assert got.t_network_contended_s == ref.t_network_contended_s
            assert got.peak_window_util == ref.peak_window_util
            assert got.backlogged_window_frac == ref.backlogged_window_frac
