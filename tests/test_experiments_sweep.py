"""Batched experiment-sweep subsystem: batched == serial equivalence, the
content-hash cache, grid expansion, and the benchmark CSV contract."""
import os
import re
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.noc import FlattenedButterfly, Mesh2D, Torus2D, Torus3D
from repro.core.partition import powerlaw_partition, random_partition
from repro.core.placement import (
    Placement,
    auto_mesh_for_parts,
    greedy_placement,
    random_placement,
)
from repro.core.simulator import simulate
from repro.core.traffic import traffic_from_partition
from repro.experiments.batched import (
    batched_weighted_hops,
    routing_operator,
    simulate_batch,
    simulate_serial,
)
from repro.experiments.cache import SweepCache, graph_digest
from repro.experiments.grid import GRIDS, grid_by_name
from repro.experiments.sweep import figure_comparisons, run_sweep
from repro.graph.generators import rmat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _configs(n_graphs=3, parts=4, topology=None, seed=0):
    """(traffics, placements) for a mixed proposed/baseline batch."""
    topo = topology or auto_mesh_for_parts(parts)
    traffics, placements = [], []
    for i in range(n_graphs):
        g = rmat(120, 900, seed=seed + i)
        for part_fn, place_seed in ((powerlaw_partition, 0), (random_partition, i + 1)):
            p = part_fn(g.src, g.dst, g.num_nodes, parts)
            t = traffic_from_partition(p, g.src, g.dst)
            traffics.append(t)
            placements.append(random_placement(t.num_logical, topo, seed=place_seed))
    return traffics, placements


class TestBatchedEquivalence:
    @pytest.mark.parametrize("topology", ["mesh2d", "fbutterfly"])
    def test_numpy_backend_matches_serial_simulate(self, topology):
        parts = 4
        topo = auto_mesh_for_parts(parts, topology)
        traffics, placements = _configs(3, parts, topo)
        iters = np.arange(1, len(traffics) + 1)
        batched = simulate_batch(traffics, placements, num_iterations=iters, backend="numpy")
        for t, p, it, b in zip(traffics, placements, iters, batched):
            s = simulate(t, p, num_iterations=int(it))
            for field in (
                "exec_time_s", "energy_j", "avg_hops", "total_bytes", "byte_hops",
                "t_compute_s", "t_network_s", "t_serialization_s", "e_network_j",
                "e_compute_j",
            ):
                assert getattr(b, field) == pytest.approx(
                    getattr(s, field), rel=1e-12, abs=1e-30
                ), field

    def test_jax_backend_matches_serial_simulate(self):
        pytest.importorskip("jax")
        traffics, placements = _configs(2, 4)
        batched = simulate_batch(traffics, placements, num_iterations=3, backend="jax")
        for t, p, b in zip(traffics, placements, batched):
            s = simulate(t, p, num_iterations=3)
            # jax runs f32 on CPU by default — looser tolerance.
            assert b.exec_time_s == pytest.approx(s.exec_time_s, rel=1e-4)
            assert b.energy_j == pytest.approx(s.energy_j, rel=1e-4)
            assert b.avg_hops == pytest.approx(s.avg_hops, rel=1e-4)

    def test_torus3d_routes_exactly_and_matches_serial(self):
        # Torus3D now carries wrap-aware dimension-ordered routing, so the
        # batched path builds an exact operator instead of falling back.
        topo = Torus3D(2, 2, 4)
        assert routing_operator(topo) is not None
        g = rmat(80, 500, seed=1)
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, 4)
        t = traffic_from_partition(p, g.src, g.dst)
        pl = random_placement(t.num_logical, topo, seed=0)
        (b,) = simulate_batch([t], [pl], backend="numpy")
        s = simulate(t, pl)
        assert b.exec_time_s == pytest.approx(s.exec_time_s, rel=1e-12)
        assert b.t_serialization_s == pytest.approx(s.t_serialization_s, rel=1e-12)

    def test_routeless_topology_uses_serial_fallback(self):
        # The uniform-spread fallback stays covered via a stub topology with
        # no routing model (batched and serial must agree on it too).
        class NoRoute(Torus3D):
            def route_links_ordered(self, c0, c1, order):
                return None

        topo = NoRoute(2, 2, 4, name="noroute3d")
        assert routing_operator(topo) is None
        g = rmat(80, 500, seed=1)
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, 4)
        t = traffic_from_partition(p, g.src, g.dst)
        pl = random_placement(t.num_logical, topo, seed=0)
        (b,) = simulate_batch([t], [pl], backend="numpy")
        s = simulate(t, pl)
        assert b.exec_time_s == pytest.approx(s.exec_time_s, rel=1e-12)
        assert b.t_serialization_s == pytest.approx(s.t_serialization_s, rel=1e-12)

    def test_mixed_topologies_in_one_batch(self):
        """Groups with different topologies evaluate independently but return
        in input order."""
        t1, p1 = _configs(1, 4, auto_mesh_for_parts(4, "mesh2d"))
        t2, p2 = _configs(1, 4, auto_mesh_for_parts(4, "fbutterfly"), seed=5)
        traffics, placements = t1 + t2, p1 + p2
        batched = simulate_batch(traffics, placements, backend="numpy")
        for t, p, b in zip(traffics, placements, batched):
            assert b.exec_time_s == pytest.approx(simulate(t, p).exec_time_s, rel=1e-12)

    def test_batched_faster_than_serial_loop(self):
        """Acceptance: a ≥4-config sweep is measurably faster batched."""
        traffics, placements = _configs(8, 16)  # 16 configs on an 8×8 mesh
        assert len(traffics) >= 4
        simulate_batch(traffics, placements, backend="numpy")  # warm caches
        t0 = time.perf_counter()
        simulate_batch(traffics, placements, backend="numpy")
        t_batched = time.perf_counter() - t0
        t0 = time.perf_counter()
        simulate_serial(traffics, placements)
        t_serial = time.perf_counter() - t0
        assert t_batched < t_serial, (t_batched, t_serial)

    def test_batched_weighted_hops_matches_placement(self):
        topo = Mesh2D(4, 4)
        rng = np.random.default_rng(0)
        sites, weights, expect = [], [], []
        for i in range(5):
            w = rng.random((8, 8))
            pl = random_placement(8, topo, seed=i)
            sites.append(pl.site)
            weights.append(w)
            expect.append(pl.weighted_hops(w))
        got = batched_weighted_hops(np.stack(weights), np.stack(sites), topo, backend="numpy")
        np.testing.assert_allclose(got, expect, rtol=1e-12)

    def test_routing_operator_covers_fbutterfly(self):
        """FB: ≤2 links per route, one per differing dimension."""
        topo = FlattenedButterfly(3, 3)
        op = routing_operator(topo)
        per_pair = np.asarray(op.sum(axis=0)).reshape(9, 9)
        d = topo.distance_matrix()
        np.testing.assert_array_equal(per_pair, d)

    @pytest.mark.parametrize("topo", [Torus2D(4, 4), Torus2D(5, 3)])
    def test_routing_operator_matches_torus_wraparound_metric(self, topo):
        """Torus: the operator's per-pair link count equals the wraparound
        hop metric (ROADMAP: link loads previously stepped the long way)."""
        op = routing_operator(topo)
        n = topo.num_nodes
        per_pair = np.asarray(op.sum(axis=0)).reshape(n, n)
        np.testing.assert_array_equal(per_pair, topo.distance_matrix())

    def test_torus2d_batched_matches_serial(self):
        topo = Torus2D(4, 4)
        traffics, placements = _configs(2, 4, topo)
        batched = simulate_batch(traffics, placements, backend="numpy")
        for t, p, b in zip(traffics, placements, batched):
            s = simulate(t, p)
            assert b.exec_time_s == pytest.approx(s.exec_time_s, rel=1e-12)
            assert b.t_serialization_s == pytest.approx(s.t_serialization_s, rel=1e-12)


class TestSweepCache:
    def test_trace_roundtrip_identical(self, tmp_path):
        g = rmat(100, 700, seed=2)
        c1 = SweepCache(tmp_path)
        tr1 = c1.trace(g, "bfs")
        assert c1.stats.trace_misses == 1
        c2 = SweepCache(tmp_path)  # fresh instance, same dir
        tr2 = c2.trace(g, "bfs")
        assert c2.stats.trace_hits == 1 and c2.stats.trace_misses == 0
        np.testing.assert_array_equal(tr1.edge_activity, tr2.edge_activity)
        np.testing.assert_array_equal(tr1.vertex_activity, tr2.vertex_activity)
        assert tr1.num_iterations == tr2.num_iterations

    def test_traffic_identical_on_second_run(self, tmp_path):
        """Acceptance: the sweep cache returns identical traffic matrices."""
        g = rmat(100, 700, seed=3)
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, 4)
        c = SweepCache(tmp_path)
        tr = c.trace(g, "pagerank", max_iterations=10)
        t1 = c.traffic(g, p, tr)
        t2 = c.traffic(g, p, tr)
        assert c.stats.traffic_hits == 1
        np.testing.assert_array_equal(t1.bytes_matrix, t2.bytes_matrix)
        assert t1.phase_bytes == t2.phase_bytes

    def test_cache_key_is_content_sensitive(self, tmp_path):
        g1 = rmat(100, 700, seed=4)
        g2 = rmat(100, 700, seed=5)
        assert graph_digest(g1) != graph_digest(g2)
        c = SweepCache(tmp_path)
        c.trace(g1, "bfs")
        c.trace(g2, "bfs")  # different content → miss, not a stale hit
        assert c.stats.trace_misses == 2

    def test_disabled_cache_recomputes(self):
        g = rmat(64, 300, seed=6)
        c = SweepCache(None)
        c.trace(g, "bfs")
        c.trace(g, "bfs")
        assert c.stats.trace_misses == 2


class TestGridAndSweep:
    def test_paper_grid_shape(self):
        grid = GRIDS["paper"]
        cfgs = grid.expand()
        assert len(cfgs) == grid.num_configs == 48
        assert sum(c.is_baseline for c in cfgs) == 24

    def test_unknown_grid_raises(self):
        with pytest.raises(ValueError, match="unknown grid"):
            grid_by_name("nope")

    def test_torus_grid_shape(self):
        """The wrap-link grid crosses mesh2d/torus2d at two mesh sizes under
        three schemes: pinned greedy (every searched config takes the batched
        construction), the constructive `auto` arm (torus-native layouts on
        torus2d, no search), and the random baseline."""
        grid = GRIDS["torus"]
        cfgs = grid.expand()
        assert len(cfgs) == grid.num_configs == 72
        assert {c.topology for c in cfgs} == {"mesh2d", "torus2d"}
        assert {c.num_parts for c in cfgs} == {16, 25}
        assert {c.placement for c in cfgs} == {"greedy", "auto", "random"}
        assert sum(c.is_baseline for c in cfgs) == 24

    def test_torus_sweep_smoke_through_run_cli(self, tmp_path):
        """Satellite acceptance: `run.py --grid torus --scale 0.001` stores
        the artifact whose §Torus section the paper render consumes."""
        from repro.experiments.run import main as run_main

        rc = run_main(
            [
                "--grid", "torus", "--scale", "0.001",
                "--cache-dir", str(tmp_path / "cache"),
                "--sweeps-dir", str(tmp_path / "sweeps"),
                "--no-serial-check", "--backend", "numpy", "-q",
            ]
        )
        assert rc == 0
        import json as json_lib

        payload = json_lib.load(open(tmp_path / "sweeps" / "torus.json"))
        assert len(payload["records"]) == 72
        ps = payload["placement_stats"]
        assert ps["batched_configs"] == 36 and ps["greedy_constructed"] == 24
        assert ps["torus_constructed"] == 12  # the torus2d constructive arm
        assert ps["serial_configs"] == 24  # the random-layout baselines
        # The physical claim the grid exists to demonstrate: under the
        # randomized baseline (mesh-spanning routes) the wrap links must cut
        # hops in every cell (measured ≥1.23× at this scale; the optimised
        # mapping hovers ~1× because its routes are already 1–2 hops).
        cells = {}
        for r in payload["records"]:
            key = (r["workload"], r["algorithm"], r["partitioner"],
                   r["placement"], r["num_parts"])
            cells.setdefault(key, {})[r["topology"]] = r
        baseline_gains = [
            pair["mesh2d"]["sim_avg_hops"] / pair["torus2d"]["sim_avg_hops"]
            for key, pair in cells.items()
            if key[2] == "random" and key[3] == "random"
            and "mesh2d" in pair and "torus2d" in pair
        ]
        assert len(baseline_gains) == 12
        assert min(baseline_gains) > 1.1, baseline_gains
        # The tentpole acceptance: on every torus2d cell the constructive
        # torus-native layout (powerlaw+auto) matches or beats the full
        # greedy+2-opt search (powerlaw+greedy) on byte-hops, with no search.
        greedy_h = {
            key[:2] + key[4:]: pair["torus2d"]["sim_byte_hops"]
            for key, pair in cells.items()
            if key[2] == "powerlaw" and key[3] == "greedy" and "torus2d" in pair
        }
        cons_h = {
            key[:2] + key[4:]: pair["torus2d"]
            for key, pair in cells.items()
            if key[2] == "powerlaw" and key[3] == "auto" and "torus2d" in pair
        }
        assert len(cons_h) == len(greedy_h) == 12
        for cell_key, rec in cons_h.items():
            assert rec["placement_method"] == "torus_quad"  # no search ran
            assert rec["sim_byte_hops"] <= greedy_h[cell_key] * (1 + 1e-9), cell_key
        from repro.experiments.report import _torus_section

        section = _torus_section(payload)
        assert "§Torus" in section and "wrap-link" in section.lower()
        assert "Constructive torus layouts vs greedy+2-opt" in section
        assert "search-time saving" in section

    def test_mini_sweep_end_to_end(self, tmp_path):
        grid = grid_by_name("mini")
        res = run_sweep(grid, cache_dir=str(tmp_path), measure_serial=True, backend="numpy")
        assert len(res.records) == 3
        comps = figure_comparisons(res.records)
        assert len(comps) == 2  # powerlaw+quad and powerlaw+greedy vs baseline
        for c in comps:
            # The proposed mapping must beat the randomized baseline.
            assert c["hop_decrease"] > 1.0
            assert c["speedup"] > 1.0
            assert c["energy_ratio"] > 1.0
        # Batched results equal per-config simulate() on the same inputs.
        for r in res.records:
            assert r.result.exec_time_s > 0
        # The batched placement engine ran (quad + greedy configs), the
        # greedy config through the stacked constructor, with H no worse
        # than the serial two_opt search it replaces.
        ps = res.placement_stats
        assert ps["batched_configs"] >= 2
        assert ps["greedy_constructed"] >= 1
        assert ps["h_worse_than_serial_configs"] == 0
        assert ps["h_vs_serial_max_ratio"] <= 1.0 + 1e-9
        assert any("2opt[batch]" in r.placement_method for r in res.records)

    def test_sweep_reuses_cache_on_second_run(self, tmp_path):
        grid = grid_by_name("mini")
        r1 = run_sweep(grid, cache_dir=str(tmp_path), measure_serial=False, backend="numpy")
        r2 = run_sweep(grid, cache_dir=str(tmp_path), measure_serial=False, backend="numpy")
        assert r2.cache_stats["trace_hits"] >= 1
        assert r2.cache_stats["trace_misses"] == 0
        for a, b in zip(r1.records, r2.records):
            assert a.result.exec_time_s == pytest.approx(b.result.exec_time_s, rel=1e-12)


CSV_ROW = re.compile(r"^[\w/.\-]+,\d+(\.\d+)?,\S.*$")


class TestBenchmarkContract:
    def test_run_py_emits_csv_rows_on_tiny_grid(self, tmp_path):
        """Acceptance: benchmarks/run.py → well-formed name,us_per_call,derived."""
        env = dict(
            os.environ,
            PYTHONPATH=os.path.join(REPO, "src"),
            BENCH_SCALE="0.0008",
            BENCH_PARTS="4",
            BENCH_CACHE=str(tmp_path),
        )
        out = subprocess.run(
            [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
             "--only", "skew,hop_count,placement,speedup,energy"],
            capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        lines = [l for l in out.stdout.splitlines() if l.strip()]
        assert lines[0] == "name,us_per_call,derived"
        body = [l for l in lines[1:] if "," in l]
        assert len(body) >= 4 + 4 + 2 + 12 + 12  # skew+fig5+placement+fig7+fig8
        for line in body:
            assert CSV_ROW.match(line), line
        assert any(l.startswith("fig7_speedup/") for l in body)
        assert any(l.startswith("fig8_energy/") for l in body)
        assert any(l.startswith("placement/serial_loop") for l in body)
        placement_rows = [l for l in body if l.startswith("placement/batched")]
        assert placement_rows
        for row in placement_rows:  # batched search must never worsen H
            h_ratio = float(row.split("h_max_ratio=")[1].split(";")[0])
            assert h_ratio <= 1.0 + 1e-6, row

    def test_report_writer_outputs_both_files(self, tmp_path):
        from repro.experiments.report import write_outputs

        grid = grid_by_name("mini")
        res = run_sweep(grid, cache_dir=str(tmp_path / "cache"), measure_serial=False,
                        backend="numpy")
        md, js = write_outputs(
            res,
            md_path=str(tmp_path / "EXPERIMENTS.md"),
            json_path=str(tmp_path / "BENCH_sweep.json"),
            dryrun_dir=str(tmp_path / "nodir"),
            perf_dir=str(tmp_path / "nodir"),
            sweeps_dir=str(tmp_path / "nodir"),
        )
        text = open(md).read()
        for section in ("## §Calibration", "## §Dry-run", "## §Roofline", "## §Perf",
                        "## Fig. 5", "## Fig. 7"):
            assert section in text, section
        import json as json_lib

        payload = json_lib.load(open(js))
        assert payload["records"] and payload["comparisons"]
        assert payload["grid"]["name"] == "mini"
        assert payload["placement_stats"]["batched_configs"] >= 1

    def test_extra_sweep_artifacts_render_sections(self, tmp_path):
        """§Ablation / §Mesh-scaling / §Torus render from artifacts/sweeps/*.json."""
        from repro.experiments.report import save_sweep_artifact, write_outputs

        grid = grid_by_name("mini")
        res = run_sweep(grid, cache_dir=str(tmp_path / "cache"), measure_serial=False,
                        backend="numpy")
        sweeps = tmp_path / "sweeps"
        # Stand-ins for the secondary grids: payload shape is what the
        # renderers consume, the grid name keys the section.
        for name in ("ablation", "meshscale", "torus"):
            import dataclasses as dc

            res2 = dc.replace(res, grid=dc.replace(res.grid, name=name))
            save_sweep_artifact(res2, str(sweeps))
        md, _ = write_outputs(
            res,
            md_path=str(tmp_path / "E.md"),
            json_path=str(tmp_path / "B.json"),
            dryrun_dir=str(tmp_path / "nodir"),
            perf_dir=str(tmp_path / "nodir"),
            sweeps_dir=str(sweeps),
        )
        text = open(md).read()
        assert "## §Ablation" in text
        assert "## §Mesh scaling" in text
        assert "## §Torus" in text


class TestFreshnessAudit:
    def _written(self, tmp_path):
        from repro.experiments.report import write_outputs

        res = run_sweep(
            grid_by_name("mini"), cache_dir=str(tmp_path / "cache"),
            measure_serial=False, backend="numpy",
        )
        md, js = write_outputs(
            res,
            md_path=str(tmp_path / "E.md"),
            json_path=str(tmp_path / "B.json"),
            dryrun_dir=str(tmp_path / "nodir"),
            perf_dir=str(tmp_path / "nodir"),
            sweeps_dir=str(tmp_path / "sweeps"),
        )
        return res, md, js

    def test_fresh_report_passes(self, tmp_path):
        from repro.experiments.report import experiments_md_issues

        _, md, js = self._written(tmp_path)
        assert experiments_md_issues(md, js, str(tmp_path / "sweeps")) == []

    def test_unrendered_sweep_artifact_is_stale(self, tmp_path):
        import dataclasses as dc

        from repro.experiments.report import experiments_md_issues, save_sweep_artifact

        res, md, js = self._written(tmp_path)
        res2 = dc.replace(res, grid=dc.replace(res.grid, name="torus"))
        save_sweep_artifact(res2, str(tmp_path / "sweeps"))  # stored after the render
        issues = experiments_md_issues(md, js, str(tmp_path / "sweeps"))
        assert issues and "torus" in issues[0]

    def test_rendered_section_with_missing_artifact_is_stale(self, tmp_path):
        import dataclasses as dc

        from repro.experiments.report import (
            experiments_md_issues,
            save_sweep_artifact,
            write_outputs,
        )

        res = run_sweep(
            grid_by_name("mini"), cache_dir=str(tmp_path / "cache"),
            measure_serial=False, backend="numpy",
        )
        sweeps = tmp_path / "sweeps"
        res2 = dc.replace(res, grid=dc.replace(res.grid, name="torus"))
        save_sweep_artifact(res2, str(sweeps))
        md, js = write_outputs(
            res,
            md_path=str(tmp_path / "E.md"), json_path=str(tmp_path / "B.json"),
            dryrun_dir=str(tmp_path / "nodir"), perf_dir=str(tmp_path / "nodir"),
            sweeps_dir=str(sweeps),
        )
        assert experiments_md_issues(md, js, str(sweeps)) == []
        os.remove(sweeps / "torus.json")  # report still renders §Torus
        issues = experiments_md_issues(md, js, str(sweeps))
        assert issues and "torus" in issues[0] and "missing" in issues[0]

    def test_mismatched_payload_is_stale(self, tmp_path):
        import json as json_lib

        from repro.experiments.report import experiments_md_issues

        _, md, js = self._written(tmp_path)
        payload = json_lib.load(open(js))
        payload["records"] = payload["records"][:-1]  # drift the config count
        json_lib.dump(payload, open(js, "w"))
        issues = experiments_md_issues(md, js, str(tmp_path / "sweeps"))
        assert issues and "config count" in issues[0]

    def test_check_cli_exit_codes(self, tmp_path):
        from repro.experiments.report import main as report_main

        _, md, js = self._written(tmp_path)
        args = ["--check", "--md", md, "--json", js, "--sweeps-dir", str(tmp_path / "sweeps")]
        assert report_main(args) == 0
        os.remove(js)
        assert report_main(args) == 1
