"""Windowed contention simulator (repro.nocsim): routing cross-validation,
the uncongested-limit convergence contract, numpy↔jax backend parity, the
phase-multiplexing excess, routing arms, and the sweep/report wiring."""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.noc import (
    FlattenedButterfly,
    Mesh2D,
    Torus2D,
    Torus3D,
    topology_by_name,
)
from repro.core.partition import powerlaw_partition
from repro.core.placement import Placement, auto_mesh_for_parts, place, random_placement
from repro.core.simulator import SimParams, simulate
from repro.core.traffic import TrafficMatrix, traffic_from_partition
from repro.graph.generators import rmat
from repro.nocsim import (
    NocSimParams,
    contended_batch,
    contention_sweep_payload,
    simulate_contended,
)
from repro.nocsim.routes import assign_adaptive2, route_operators

ALL_TOPOLOGIES = (
    Mesh2D(4, 5),
    FlattenedButterfly(4, 4),
    Torus2D(4, 4),
    Torus2D(5, 3),
    Torus3D(3, 3, 2),
)


def _random_traffic(parts: int, seed: int, density: float = 0.4) -> TrafficMatrix:
    rng = np.random.default_rng(seed)
    n = 4 * parts
    m = rng.random((n, n)) * (rng.random((n, n)) < density) * 1000.0
    np.fill_diagonal(m, 0.0)
    return TrafficMatrix(
        num_parts=parts,
        bytes_matrix=m,
        phase_bytes={"process": float(m.sum()), "reduce": 0.0, "apply": 0.0},
    )


class TestRoutingCrossValidation:
    """Satellites 1–2: every topology that implements routing must agree
    with its own distance metric, for every dimension traversal order."""

    @pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=lambda t: f"{t.name}{t.num_nodes}")
    def test_route_length_equals_distance(self, topo):
        import itertools

        d = topo.distance_matrix()
        coords = topo.coords()
        ndim = coords.shape[1]
        orders = [None] + list(itertools.permutations(range(ndim)))
        for i, c0 in enumerate(coords):
            for j, c1 in enumerate(coords):
                for order in orders:
                    links = topo.route_links_ordered(tuple(c0), tuple(c1), order)
                    assert len(links) == d[i, j]
                    # contiguity: each link leaves where the previous arrived
                    pos = tuple(c0)
                    for ln in links:
                        assert ln[:ndim] == pos
                        pos = ln[ndim:]
                    if links:
                        assert pos == tuple(c1)

    def test_route_links_matches_natural_order(self):
        topo = Torus2D(4, 4)
        assert topo.route_links((0, 0), (3, 2)) == topo.route_links_ordered(
            (0, 0), (3, 2), None
        )

    def test_torus3d_wraps_shorter_way(self):
        topo = Torus3D(4, 4, 4)
        # (0,0,0) → (3,0,0): one wrap link, not three mesh steps.
        assert topo.route_links((0, 0, 0), (3, 0, 0)) == [(0, 0, 0, 3, 0, 0)]
        # Z dimension last in the natural order.
        links = topo.route_links((0, 0, 0), (1, 1, 1))
        assert len(links) == 3
        assert links[-1] == (1, 1, 0, 1, 1, 1)

    def test_torus3d_routing_operator_is_exact_now(self):
        """ROADMAP item: Torus3D used to fall back to the uniform spread."""
        from repro.experiments.batched import routing_operator

        op = routing_operator(Torus3D(3, 3, 2))
        assert op is not None
        d = Torus3D(3, 3, 2).distance_matrix()
        # every column's nnz equals the pair's hop count
        nnz = np.asarray((op > 0).sum(axis=0)).ravel().reshape(18, 18)
        assert (nnz == d).all()


class TestUncongestedConvergence:
    """Satellite 3: the contended T_network equals the analytic one in the
    uncongested limit — and for any separable profile the contended drain
    equals the analytic serialization term at EVERY rate."""

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        rate=st.sampled_from([1e-3, 1e-2, 0.1, 0.5, 1.0]),
        backend=st.sampled_from(["numpy", "jax"]),
    )
    def test_uniform_low_rate_matches_analytic(self, seed, rate, backend):
        t = _random_traffic(4, seed)
        topo = Mesh2D(4, 4)
        pl = random_placement(t.num_logical, topo, seed=seed + 1)
        ana = simulate(t, pl)
        noc = simulate_contended(
            t,
            pl,
            noc_params=NocSimParams(profile="uniform", inj_rate=rate, windows=16),
            backend=backend,
        )
        tol = 1e-9 if backend == "numpy" else 1e-6
        assert noc.t_drain_s == pytest.approx(ana.t_serialization_s, rel=tol)
        # zero up to fp noise: the exactly-saturated peak link's normalised
        # injection can exceed capacity by an ulp of the schedule dot product
        assert noc.mean_queue_delay_s == pytest.approx(0.0, abs=1e-15)
        # full contended network term == full analytic network term
        assert noc.t_network_contended_s == pytest.approx(ana.t_network_s, rel=tol)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), rate=st.sampled_from([0.5, 1.0, 2.0, 8.0]))
    def test_separable_profiles_reproduce_analytic_drain(self, seed, rate):
        """Uniform AND burst injections scale every link by one time profile,
        so the per-window bottleneck is the aggregate-peak link throughout
        and the contended drain telescopes to exactly peak/bw."""
        t = _random_traffic(4, seed)
        pl = random_placement(t.num_logical, Torus2D(4, 4), seed=seed)
        ana = simulate(t, pl)
        for profile in ("uniform", "burst"):
            noc = simulate_contended(
                t, pl, noc_params=NocSimParams(profile=profile, inj_rate=rate)
            )
            assert noc.t_drain_s == pytest.approx(ana.t_serialization_s, rel=1e-9)

    def test_contended_never_below_analytic(self):
        t = _random_traffic(4, 7)
        pl = random_placement(t.num_logical, Mesh2D(4, 4), seed=2)
        for profile in ("uniform", "phases", "burst"):
            for rate in (0.5, 1.0, 4.0):
                noc = simulate_contended(
                    t, pl, noc_params=NocSimParams(profile=profile, inj_rate=rate)
                )
                assert noc.contention_excess >= 1.0 - 1e-12
                assert noc.t_drain_s >= noc.t_serialization_s * (1 - 1e-12)


class TestBackendParity:
    """The numpy reference and the stacked jax scan agree within 1e-6
    relative on the contended T_network (the acceptance contract)."""

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        profile=st.sampled_from(["uniform", "phases", "burst"]),
        rate=st.sampled_from([0.25, 1.0, 4.0]),
    )
    def test_numpy_jax_parity(self, seed, profile, rate):
        pytest.importorskip("jax")
        t = _random_traffic(4, seed)
        pl = random_placement(t.num_logical, Mesh2D(4, 4), seed=seed)
        params = NocSimParams(profile=profile, inj_rate=rate)
        r_np = simulate_contended(t, pl, noc_params=params, backend="numpy")
        r_jx = simulate_contended(t, pl, noc_params=params, backend="jax")
        assert r_jx.t_network_contended_s == pytest.approx(
            r_np.t_network_contended_s, rel=1e-6
        )
        assert r_jx.t_drain_s == pytest.approx(r_np.t_drain_s, rel=1e-6)

    def test_batch_matches_serial_and_pads_mixed_topologies(self):
        """One stacked call over configs of DIFFERENT topologies (different
        link counts — the padded axis) equals per-config serial calls."""
        traffics, placements = [], []
        for seed, topo in ((0, Mesh2D(4, 4)), (1, Torus2D(4, 4)), (2, FlattenedButterfly(4, 4))):
            t = _random_traffic(4, seed)
            traffics.append(t)
            placements.append(random_placement(t.num_logical, topo, seed=seed))
        params = NocSimParams(profile="phases")
        batch = contended_batch(traffics, placements, noc_params=params, backend="numpy")
        for t, pl, b in zip(traffics, placements, batch):
            s = simulate_contended(t, pl, noc_params=params, backend="numpy")
            assert b.t_network_contended_s == pytest.approx(s.t_network_contended_s, rel=1e-12)
            assert b.p99_latency_s == pytest.approx(s.p99_latency_s, rel=1e-12)


class TestContentionPhysics:
    def test_phase_multiplexed_hotspots_exceed_aggregate_peak(self):
        """Two equal flows on disjoint links in different PHASES: the
        aggregate peak sees each link at half the serialized traffic, but
        phases cannot overlap — the windowed drain is ~2× the analytic."""
        parts = 2
        n = 4 * parts
        m = np.zeros((n, n))
        # process flow: ET part0 → vProp part0 (logical 0 → 2)
        m[0, 2] = 64_000.0
        # reduce flow: eProp part1 → vTemp part1 (logical 7 → 5)
        m[7, 5] = 64_000.0
        t = TrafficMatrix(
            num_parts=parts,
            bytes_matrix=m,
            phase_bytes={"process": 64_000.0, "reduce": 64_000.0, "apply": 0.0},
        )
        topo = Mesh2D(4, 2)
        # far-apart placements so the two flows share no link
        site = np.array([0, 1, 2, 3, 4, 5, 6, 7])
        pl = Placement(topo, site, "manual")
        noc = simulate_contended(
            t, pl, noc_params=NocSimParams(profile="phases", inj_rate=0.01, windows=16)
        )
        assert noc.contention_excess == pytest.approx(2.0, rel=1e-6)

    def test_queueing_appears_past_saturation(self):
        t = _random_traffic(4, 3)
        pl = random_placement(t.num_logical, Mesh2D(4, 4), seed=3)
        # a burst concentrating all bytes into burst_frac of the horizon stays
        # backlog-free only below inj_rate ≈ burst_frac (0.25 default)
        lo = simulate_contended(t, pl, noc_params=NocSimParams(profile="burst", inj_rate=0.1))
        hi = simulate_contended(t, pl, noc_params=NocSimParams(profile="burst", inj_rate=8.0))
        assert lo.mean_queue_delay_s == pytest.approx(0.0, abs=1e-15)
        assert hi.mean_queue_delay_s > 0.0
        assert hi.p99_latency_s > lo.p99_latency_s
        assert hi.backlogged_window_frac > 0.0

    def test_adaptive2_relieves_a_crafted_hotspot(self):
        """Two flows whose X-Y routes share a link but whose Y-X alternatives
        are disjoint: the two-choice assignment must split them."""
        topo = Mesh2D(3, 3)
        ops = route_operators(topo)
        n = topo.num_nodes
        flow = np.zeros(n * n)
        # (0,0)→(2,1) and (1,0)→(2,2): X-Y routes both cross (2,0)→(2,1)...
        a = 0 * 3 + 0  # (0,0)
        b = 2 * 3 + 1  # (2,1)
        c = 1 * 3 + 0  # (1,0)
        d = 2 * 3 + 2  # (2,2)
        flow[a * n + b] = 100.0
        flow[c * n + d] = 100.0
        rev = assign_adaptive2(ops, flow)
        nat_loads = ops.nat @ flow
        mixed = np.where(rev, 0.0, 1.0)
        loads = ops.nat @ (flow * mixed) + ops.rev @ (flow * (1 - mixed))
        assert loads.max() < nat_loads.max()

    def test_adaptive2_preserves_hop_counts(self):
        """Both candidate routes are minimal, so byte-hops are unchanged."""
        t = _random_traffic(4, 11)
        pl = random_placement(t.num_logical, Torus2D(4, 4), seed=11)
        dor = simulate_contended(t, pl, noc_params=NocSimParams(routing="dor"))
        ad = simulate_contended(t, pl, noc_params=NocSimParams(routing="adaptive2"))
        # saturation bound may move (loads redistribute) but the analytic
        # serialization of adaptive2 can never exceed... it CAN change; hop
        # counts cannot: compare the latency floor (pure hop latency).
        lo_d = simulate_contended(
            t, pl, noc_params=NocSimParams(routing="dor", profile="uniform", inj_rate=1e-3)
        )
        lo_a = simulate_contended(
            t,
            pl,
            noc_params=NocSimParams(routing="adaptive2", profile="uniform", inj_rate=1e-3),
        )
        assert lo_a.mean_latency_s == pytest.approx(lo_d.mean_latency_s, rel=1e-9)
        assert ad.windows == dor.windows

    def test_bad_params_raise(self):
        with pytest.raises(ValueError, match="burst_frac"):
            NocSimParams(profile="burst", burst_frac=2.0)
        with pytest.raises(ValueError, match="windows"):
            NocSimParams(windows=0)
        with pytest.raises(ValueError, match="inj_rate"):
            NocSimParams(inj_rate=0.0)
        with pytest.raises(ValueError, match="profile"):
            NocSimParams(profile="sawtooth")
        with pytest.raises(ValueError, match="routing"):
            NocSimParams(routing="valiant")
        with pytest.raises(ValueError, match="latency_q"):
            NocSimParams(latency_q=0.0)

    def test_rejects_topology_without_routing(self):
        class NoRoute(Mesh2D):
            def route_links_ordered(self, c0, c1, order):
                return None

        topo = NoRoute(4, 4, name="noroute")
        t = _random_traffic(4, 0)
        pl = random_placement(t.num_logical, topo, seed=0)
        with pytest.raises(ValueError, match="routing"):
            simulate_contended(t, pl)


class TestSimulateIntegration:
    def test_simulate_contention_kwarg(self, rmat_graph):
        g = rmat_graph
        p = powerlaw_partition(g.src, g.dst, g.num_nodes, 4)
        t = traffic_from_partition(p, g.src, g.dst)
        topo = auto_mesh_for_parts(4, "mesh2d")
        pl = place(t, p, topo, method="quad")
        plain = simulate(t, pl)
        cont = simulate(t, pl, contention=NocSimParams())
        assert plain.t_network_contended_s is None
        assert cont.t_network_contended_s is not None
        assert cont.t_network_contended_s >= plain.t_network_s * (1 - 1e-12)
        # analytic fields stay comparable side by side
        assert cont.t_network_s == pytest.approx(plain.t_network_s, rel=1e-12)
        assert cont.exec_time_s == pytest.approx(
            plain.t_compute_s + cont.t_network_contended_s, rel=1e-12
        )


class TestSweepAndReportWiring:
    @pytest.fixture(scope="class")
    def tiny_contention_sweep(self):
        from repro.experiments.grid import GRIDS
        from repro.experiments.sweep import run_sweep

        grid = dataclasses.replace(
            GRIDS["contention"],
            workloads=("amazon",),
            algorithms=("bfs",),
            parts=(4,),
            scale=0.001,
            # avoid the exact-MILP auto route; greedy also covers torus3d,
            # where the 2-D quad construction does not apply
            placements=("greedy", "random"),
        )
        return run_sweep(grid, cache_dir=None, measure_serial=False)

    def test_contention_grid_shape(self):
        from repro.experiments.grid import GRIDS

        grid = GRIDS["contention"]
        assert grid.contention
        assert set(grid.topologies) == {"mesh2d", "torus2d", "torus3d"}
        # proposed-vs-baseline pairing on every cell
        assert grid.num_configs == 24
        assert grid.buffer_depths is None  # open loop only; credit is §Backpressure

    def test_backpressure_grid_shape(self):
        from repro.experiments.grid import GRIDS

        grid = GRIDS["backpressure"]
        assert grid.contention
        assert set(grid.topologies) == {"mesh2d", "torus2d", "torus3d"}
        assert grid.buffer_depths is not None and len(grid.buffer_depths) >= 2
        assert tuple(grid.buffer_depths) == tuple(sorted(grid.buffer_depths))
        assert GRIDS["minicredit"].buffer_depths == (1.0, 4.0)

    def test_sweep_contention_payload(self, tiny_contention_sweep):
        payload = tiny_contention_sweep.to_dict()
        cont = payload["contention"]
        assert cont is not None
        # every config × both routing arms
        assert len(cont["records"]) == 2 * len(payload["records"])
        assert {r["routing"] for r in cont["records"]} == {"dor", "adaptive2"}
        parity = cont["backend_parity_max_rel"]
        assert parity is not None and parity <= cont["parity_rtol"]
        for r in cont["records"]:
            assert r["t_network_contended_s"] > 0
            assert r["contention_excess"] >= 1.0 - 1e-12

    def test_contention_section_renders(self, tiny_contention_sweep):
        from repro.experiments.report import _contention_section

        text = _contention_section(tiny_contention_sweep.to_dict())
        assert "`--grid contention`" in text
        assert "peak util (mapped)" in text
        assert "powerlaw+greedy" in text  # every non-baseline scheme gets a row
        assert "strictly lower" in text
        assert "jax.lax.scan" in text

    def test_check_gates_contention_parity(self, tmp_path, tiny_contention_sweep):
        """A contention artifact with drifted backends (or no parity record)
        must fail the freshness audit."""
        import json

        from repro.experiments.report import experiments_md_issues

        sweeps = tmp_path / "sweeps"
        sweeps.mkdir()
        payload = tiny_contention_sweep.to_dict()
        md = tmp_path / "EXPERIMENTS.md"
        js = tmp_path / "BENCH_sweep.json"

        def write_all(p):
            (sweeps / "contention.json").write_text(json.dumps(p))
            md.write_text(
                "## §Contention (`--grid contention`)\n"
                f"**{len(payload['records'])} configurations**\n"
                f"scale {payload['grid']['scale']:g}; backend\n"
                f"`place_batch`: {payload['placement_stats']['batched_configs']}"
                " searched configs\n"
            )
            js.write_text(json.dumps(payload))

        write_all(payload)
        assert experiments_md_issues(str(md), str(js), str(sweeps)) == []
        bad = json.loads(json.dumps(payload))
        bad["contention"]["backend_parity_max_rel"] = 1e-3
        write_all(bad)
        issues = experiments_md_issues(str(md), str(js), str(sweeps))
        assert any("parity" in i for i in issues)
        worse = json.loads(json.dumps(payload))
        worse["contention"]["records"] = []
        write_all(worse)
        issues = experiments_md_issues(str(md), str(js), str(sweeps))
        assert any("no contended records" in i for i in issues)


class TestBackpressureWiring:
    """The credit arm through the sweep → artifact → report → gate chain,
    exercised on the committed `minicredit` grid (seconds, not minutes)."""

    @pytest.fixture(scope="class")
    def minicredit_sweep(self):
        from repro.experiments.grid import GRIDS
        from repro.experiments.sweep import run_sweep

        return run_sweep(GRIDS["minicredit"], cache_dir=None, measure_serial=False)

    def test_payload_has_credit_arm(self, minicredit_sweep):
        payload = minicredit_sweep.to_dict()
        cont = payload["contention"]
        by_arm = {}
        for r in cont["records"]:
            by_arm.setdefault((r["flow_control"], r["buffer_depth"]), []).append(r)
        # open + one record set per committed depth, each covering both
        # routing arms on every config
        n_open = len(by_arm[("open", None)])
        assert set(by_arm) == {("open", None), ("credit", 1.0), ("credit", 4.0)}
        assert all(len(v) == n_open for v in by_arm.values())
        assert cont["buffer_depths"] == [1.0, 4.0]
        # infinite-credit audit: bit-exact numpy, in-parity jax
        assert cont["credit_inf_numpy_max_abs"] == 0.0
        assert cont["credit_inf_jax_max_rel"] is not None
        assert cont["credit_inf_jax_max_rel"] <= cont["parity_rtol"]
        parity = cont["backend_parity_max_rel"]
        assert parity is not None and parity <= cont["parity_rtol"]

    def test_backpressure_section_renders(self, minicredit_sweep):
        from repro.experiments.report import _backpressure_section

        text = _backpressure_section(minicredit_sweep.to_dict())
        assert "`--grid backpressure`" in text
        assert "win d=1" in text and "win d=4" in text
        assert "retained-win ratio" in text
        assert "must be 0" in text

    def test_check_gates_backpressure(self, tmp_path, minicredit_sweep):
        import json

        from repro.experiments.report import experiments_md_issues

        sweeps = tmp_path / "sweeps"
        sweeps.mkdir()
        payload = minicredit_sweep.to_dict()
        md = tmp_path / "EXPERIMENTS.md"
        js = tmp_path / "BENCH_sweep.json"

        def write_all(p):
            (sweeps / "backpressure.json").write_text(json.dumps(p))
            md.write_text(
                "## §Backpressure (`--grid backpressure`)\n"
                f"**{len(payload['records'])} configurations**\n"
                f"scale {payload['grid']['scale']:g}; backend\n"
                f"`place_batch`: {payload['placement_stats']['batched_configs']}"
                " searched configs\n"
            )
            js.write_text(json.dumps(payload))

        write_all(payload)
        issues = experiments_md_issues(str(md), str(js), str(sweeps))
        # The tiny grid is mesh2d-only, so exactly the torus3d gate trips —
        # proof the topology-coverage gate is live; the real artifact
        # committed under artifacts/sweeps covers the full axis.
        assert len(issues) == 1 and "torus3d" in issues[0]
        for mutate, needle in [
            (lambda p: p["contention"].update(credit_inf_numpy_max_abs=1e-9),
             "bit-identically"),
            (lambda p: p["contention"].update(credit_inf_jax_max_rel=1e-3),
             "infinite-credit jax"),
            (lambda p: p["contention"].update(backend_parity_max_rel=1e-3),
             "parity"),
            (lambda p: p["contention"].update(
                records=[r for r in p["contention"]["records"]
                         if r["flow_control"] != "credit"]),
             "no credit-arm records"),
            (lambda p: p["contention"].update(
                records=[r for r in p["contention"]["records"]
                         if r["buffer_depth"] != 4.0]),
             "buffer_depth axis"),
        ]:
            bad = json.loads(json.dumps(payload))
            mutate(bad)
            write_all(bad)
            issues = experiments_md_issues(str(md), str(js), str(sweeps))
            assert any(needle in i for i in issues), (needle, issues)
