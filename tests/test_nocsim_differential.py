"""Differential fuzzing of the dual-backend nocsim steppers.

The parity contract gated in sweeps (≤ 1e-6 on the final scalars) could in
principle hide compensating per-window errors; this harness compares the
float64 numpy reference against the f32 stacked jax scan STATE-BY-STATE —
every window's serviced/backlog/buffer/source timeline — on seeded random
small traffic matrices, for the open arm, the credit arm across buffer
depths, and the composed degraded+credit arm (credit flow control through
a mid-replay link failure, PR 7's two-segment stepper).  Seeds go through
the vendored `_hypothesis_compat` runner so every example reproduces on
the offline container.

Identity cases (no fuzz tolerance): an empty fault set through the
two-segment degraded path must be bit-identical to the pristine credit
run, and the degraded arm at `buffer_depth=inf` must be bit-identical to
the degraded open-loop arm — composition cannot break the convergence
contracts.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.noc import Mesh2D, Torus2D, Torus3D
from repro.core.placement import Placement
from repro.core.traffic import TrafficMatrix
from repro.faults.degraded import degraded_batch
from repro.faults.model import FaultSet, sample_link_faults
from repro.nocsim import (
    NocSimParams,
    build_credit_program,
    contended_batch,
    open_step,
    run_credit,
    run_windows,
)
from repro.nocsim.batch import PARITY_RTOL
from repro.nocsim.model import build_schedule

jax = pytest.importorskip("jax")

# Per-window f32 state tolerance: the scan carries state in f32, so each
# element wanders by a few ulps OF THE TIMELINE'S SCALE (a backlog that
# drains to ~0 in f64 keeps an f32 residue proportional to its peak, not to
# its final value).  The bound is therefore scale-aware: rtol per element
# plus an atol of rtol × the reference's peak magnitude.  Real divergence —
# a dropped window, a mis-ordered reduction — shows up orders of magnitude
# above this.  The scalar contract (PARITY_RTOL) stays the sweep gate.
STATE_RTOL = 1e-5


def _assert_state_close(got, ref, *, err_msg=""):
    scale = max(1.0, float(np.max(np.abs(ref), initial=0.0)))
    np.testing.assert_allclose(
        got, ref, rtol=STATE_RTOL, atol=STATE_RTOL * scale, err_msg=err_msg
    )


def _traffic(parts: int, seed: int, density: float = 0.4) -> TrafficMatrix:
    rng = np.random.default_rng(seed)
    n = 4 * parts
    m = (rng.random((n, n)) < density) * rng.integers(1, 2000, size=(n, n)).astype(
        np.float64
    )
    np.fill_diagonal(m, 0.0)
    return TrafficMatrix(
        num_parts=parts,
        bytes_matrix=m,
        phase_bytes={"process": float(m.sum()), "reduce": 0.0, "apply": 0.0},
    )


def _setup(topo, seed):
    parts = topo.num_nodes // 4
    t = _traffic(parts, seed)
    rng = np.random.default_rng(seed + 1)
    site = rng.permutation(topo.num_nodes)[: t.num_logical].astype(np.int64)
    return t, Placement(topo, site, "test")


def _credit_program(topo, seed, *, depth, routing="dor", windows=32):
    noc = NocSimParams(
        windows=windows, routing=routing, flow_control="credit", buffer_depth=depth
    )
    t, pl = _setup(topo, seed)
    sched = build_schedule(t, pl, noc_params=noc)
    return build_credit_program([sched], noc)


class TestOpenArmPerWindow:
    @given(seed=st.integers(0, 100_000))
    @settings(max_examples=10)
    def test_timelines_match(self, seed):
        noc = NocSimParams()
        t, pl = _setup(Mesh2D(4, 4), seed)
        s = build_schedule(t, pl, noc_params=noc)
        inj = np.zeros((noc.windows, 1, s.inj.shape[1]))
        inj[:, 0, :] = s.inj / s.cap_bytes
        (s_np, b_np), _ = run_windows(open_step("numpy"), (inj,), None)
        (s_jx, b_jx), _ = run_windows(open_step("jax"), (inj,), None)
        _assert_state_close(s_jx, s_np)
        _assert_state_close(b_jx, b_np)


class TestCreditArmPerWindow:
    @given(
        seed=st.integers(0, 100_000),
        depth=st.sampled_from([0.5, 1.0, 2.0, 8.0]),
        topo=st.sampled_from([Mesh2D(4, 4), Torus2D(4, 4), Torus3D(3, 3, 2)]),
    )
    @settings(max_examples=12)
    def test_state_timelines_match(self, seed, depth, topo):
        program = _credit_program(topo, seed, depth=depth)
        tl_np, carry_np = run_credit(program, backend="numpy")
        tl_jx, carry_jx = run_credit(program, backend="jax")
        for name in ("serviced", "eff_backlog", "buf", "src", "admitted", "arrivals"):
            _assert_state_close(
                getattr(tl_jx, name),
                getattr(tl_np, name),
                err_msg=f"{name} drifted (seed={seed}, depth={depth}, {topo.name})",
            )
        _assert_state_close(carry_jx[0], carry_np[0])
        _assert_state_close(carry_jx[1], carry_np[1])

    @given(seed=st.integers(0, 100_000), depth=st.sampled_from([0.5, 2.0]))
    @settings(max_examples=8)
    def test_scalars_within_contract(self, seed, depth):
        t, pl = _setup(Torus2D(4, 4), seed)
        noc = NocSimParams(flow_control="credit", buffer_depth=depth)
        r_np = contended_batch([t], [pl], noc_params=noc, backend="numpy")[0]
        r_jx = contended_batch([t], [pl], noc_params=noc, backend="jax")[0]
        rel = abs(r_jx.t_network_contended_s - r_np.t_network_contended_s) / abs(
            r_np.t_network_contended_s
        )
        assert rel <= PARITY_RTOL


class TestDegradedCreditComposition:
    """Credit flow control through a mid-replay link failure: the composed
    two-segment stepper keeps both backends in lockstep and degrades to
    its exact identities at the edges of the knob space."""

    @given(seed=st.integers(0, 100_000), depth=st.sampled_from([0.5, 1.0, 4.0]))
    @settings(max_examples=8)
    def test_numpy_jax_parity_under_faults(self, seed, depth):
        topo = Mesh2D(4, 4)
        t, pl = _setup(topo, seed)
        faults = sample_link_faults(topo, 0.05, seed=seed + 7)
        noc = NocSimParams(flow_control="credit", buffer_depth=depth)
        r_np = degraded_batch([t], [pl], [faults], noc_params=noc, backend="numpy")[0]
        r_jx = degraded_batch([t], [pl], [faults], noc_params=noc, backend="jax")[0]
        rel = abs(r_jx.t_network_contended_s - r_np.t_network_contended_s) / abs(
            r_np.t_network_contended_s
        )
        assert rel <= PARITY_RTOL
        # The per-window bottleneck-utilization timeline, not just scalars.
        _assert_state_close(r_jx.util_timeline, r_np.util_timeline)

    @pytest.mark.parametrize("backend", ["numpy", "jax"])
    def test_empty_faults_are_pristine_credit(self, backend):
        t, pl = _setup(Torus2D(4, 4), 21)
        noc = NocSimParams(flow_control="credit", buffer_depth=1.0)
        deg = degraded_batch([t], [pl], [FaultSet()], noc_params=noc, backend=backend)[0]
        ref = contended_batch([t], [pl], noc_params=noc, backend=backend)[0]
        # Two-segment stepping with a no-op boundary == the unchunked run.
        assert deg.t_network_contended_s == ref.t_network_contended_s
        assert deg.t_drain_s == ref.t_drain_s
        assert deg.mean_queue_delay_s == ref.mean_queue_delay_s
        np.testing.assert_array_equal(deg.util_timeline, ref.util_timeline)

    def test_degraded_infinite_credit_is_degraded_open(self):
        topo = Mesh2D(4, 4)
        t, pl = _setup(topo, 22)
        faults = sample_link_faults(topo, 0.1, seed=3)
        assert not faults.is_empty
        inf_noc = NocSimParams(flow_control="credit", buffer_depth=float("inf"))
        r_inf = degraded_batch([t], [pl], [faults], noc_params=inf_noc, backend="numpy")[0]
        r_open = degraded_batch([t], [pl], [faults], backend="numpy")[0]
        assert r_inf.t_network_contended_s == r_open.t_network_contended_s
        assert r_inf.t_drain_s == r_open.t_drain_s
        np.testing.assert_array_equal(r_inf.util_timeline, r_open.util_timeline)

    def test_backpressure_tightens_under_faults(self):
        # Sanity on the composed physics: a faulted fabric with tight
        # buffers cannot beat the same faulted fabric with infinite ones.
        topo = Mesh2D(4, 4)
        t, pl = _setup(topo, 23)
        faults = sample_link_faults(topo, 0.1, seed=5)
        times = []
        for depth in (0.5, 2.0, float("inf")):
            noc = NocSimParams(flow_control="credit", buffer_depth=depth)
            r = degraded_batch([t], [pl], [faults], noc_params=noc, backend="numpy")[0]
            times.append(r.t_network_contended_s)
        assert times[0] >= times[1] * (1 - 1e-12) >= times[2] * (1 - 1e-12) ** 2
