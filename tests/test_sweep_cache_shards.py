"""Robustness tests for the content-hashed traffic shard cache.

The sharded path (`SweepCache.traffic(edge_block=...)`) persists one .npz per
edge block plus one vertex shard, each carrying a sha256 over its payload.
These tests lock down the failure contract: a missing, truncated, or
hash-mismatched shard file triggers recompute of ONLY that shard (never a
crash, never invalidation of its neighbours), and every degraded path still
returns a bit-exact traffic matrix.
"""
import glob
import os

import numpy as np
import pytest

from repro.core.partition import powerlaw_partition
from repro.core.traffic import SparseTraffic, TrafficMatrix, traffic_from_partition
from repro.experiments.cache import SweepCache, _load_shard
from repro.graph.generators import rmat
from repro.graph.vertex_program import TraceResult


@pytest.fixture()
def setup(tmp_path):
    g = rmat(300, 2400, seed=7)
    part = powerlaw_partition(g.src, g.dst, g.num_nodes, 4)
    rng = np.random.default_rng(7)
    trace = TraceResult(
        props=np.zeros(g.num_nodes),
        num_iterations=5,
        edge_activity=rng.integers(0, 6, size=g.src.size).astype(np.float64),
        vertex_activity=rng.integers(0, 8, size=g.num_nodes).astype(np.float64),
        frontier_sizes=[g.num_nodes] * 5,
    )
    dense = traffic_from_partition(
        part, g.src, g.dst,
        edge_activity=trace.edge_activity, vertex_activity=trace.vertex_activity,
    )
    cache = SweepCache(tmp_path)
    return g, part, trace, dense, cache, tmp_path


def _shards(root):
    return sorted(glob.glob(os.path.join(str(root), "*.shard*.npz")))


def _assert_matches(t, dense):
    assert isinstance(t, SparseTraffic)
    assert np.array_equal(t.to_dense().bytes_matrix, dense.bytes_matrix)
    assert t.phase_bytes == dense.phase_bytes


def test_cold_then_warm_round_trip(setup):
    g, part, trace, dense, cache, root = setup
    t = cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    _assert_matches(t, dense)
    # E=2400 / block 500 → 5 edge shards, + 1 vertex shard
    assert len(_shards(root)) == 6
    assert cache.stats.shard_misses == 6 and cache.stats.shard_hits == 0
    t2 = cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    _assert_matches(t2, dense)
    assert cache.stats.shard_misses == 6 and cache.stats.shard_hits == 6


def test_truncated_shard_recomputes_only_that_shard(setup):
    g, part, trace, dense, cache, root = setup
    cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    victim = _shards(root)[2]
    data = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(data[: len(data) // 2])
    assert _load_shard(victim) is None  # corrupt zip → None, not an exception
    before = cache.stats.shard_misses
    t = cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    _assert_matches(t, dense)
    assert cache.stats.shard_misses == before + 1  # only the victim recomputed
    assert _load_shard(victim) is not None  # and rewritten valid


def test_hash_mismatch_invalidates_only_affected_shard(setup):
    g, part, trace, dense, cache, root = setup
    cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    victim = _shards(root)[0]
    loaded = np.load(victim)
    keys, vals = loaded["keys"], loaded["vals"].copy()
    vals[0] += 8.0  # valid zip, wrong content vs stored sha
    np.savez_compressed(
        victim + ".tmp.npz", keys=keys, vals=vals,
        total=loaded["total"], sha=loaded["sha"],
    )
    os.replace(victim + ".tmp.npz", victim)
    assert _load_shard(victim) is None
    before = cache.stats.shard_misses
    t = cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    _assert_matches(t, dense)
    assert cache.stats.shard_misses == before + 1


def test_missing_shard_recomputes_only_that_shard(setup):
    g, part, trace, dense, cache, root = setup
    cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    os.remove(_shards(root)[4])
    before = cache.stats.shard_misses
    t = cache.traffic(g, part, trace, layout="sparse", edge_block=500)
    _assert_matches(t, dense)
    assert cache.stats.shard_misses == before + 1


def test_sharded_layouts_and_single_file_path_agree(setup):
    g, part, trace, dense, cache, root = setup
    td = cache.traffic(g, part, trace, layout="dense", edge_block=500)
    assert isinstance(td, TrafficMatrix)
    assert np.array_equal(td.bytes_matrix, dense.bytes_matrix)
    ta = cache.traffic(g, part, trace, layout="auto", edge_block=500)
    assert isinstance(ta, TrafficMatrix)  # 16 shards ≤ dense hatch
    # historical single-file path, untouched by sharding
    t1 = cache.traffic(g, part, trace)
    assert isinstance(t1, TrafficMatrix)
    assert np.array_equal(t1.bytes_matrix, dense.bytes_matrix)
    assert cache.stats.traffic_misses == 1
    cache.traffic(g, part, trace)
    assert cache.stats.traffic_hits == 1


def test_uncached_sharded_compute(setup):
    g, part, trace, dense, _cache, _root = setup
    cache = SweepCache(None)  # no root → pure compute, still block-streamed
    t = cache.traffic(g, part, trace, layout="sparse", edge_block=100)
    _assert_matches(t, dense)
    assert cache.stats.shard_misses == 25  # ceil(2400/100) + 1, nothing stored
