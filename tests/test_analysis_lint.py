"""The analysis layer (`repro.analysis`): rule catalogue, suppression and
baseline lifecycle, the @parity_pair registry, and the generated
ARCHITECTURE parity table.

Each rule gets a paired positive/negative fixture (the positive snippet
violates exactly one clause, the negative is the minimal compliant
rewrite), and the two ISSUE acceptance mutations are exercised against a
copy of the REAL tree: stripping one `@parity_pair` decorator must trip
RPL006, and injecting a `float(tracer)` into the nocsim `lax.scan` body
must trip RPL001.
"""
from __future__ import annotations

import json
import shutil
import textwrap
from pathlib import Path

import pytest

from repro.analysis import parity_table
from repro.analysis.engine import (
    Finding,
    diff_vs_baseline,
    lint_paths,
    load_baseline,
    write_baseline,
)
from repro.analysis.lint import main as lint_main
from repro.analysis.registry import (
    PARITY_KINDS,
    ParityEntry,
    load_registry,
    parity_pair,
)
from repro.analysis.rules import ALL_RULES, rule_catalog

REPO_ROOT = Path(__file__).resolve().parents[1]
SRC = REPO_ROOT / "src"
BASELINE = REPO_ROOT / "artifacts" / "lint_baseline.json"


def lint_snippet(tmp_path, source, relname="repro/nocsim/mod_under_test.py"):
    """Write `source` into a tmp tree shaped like the real package layout
    (rules key on `repro/<pkg>/` path segments) and lint the whole tree."""
    target = tmp_path / relname
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(textwrap.dedent(source))
    return lint_paths([str(tmp_path)]).findings


def rules_fired(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# RPL001 — tracer leaks in traced control-flow bodies
# ---------------------------------------------------------------------------


class TestTracerLeak:
    def test_float_cast_on_traced_value_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from jax import lax

            def kernel(xs):
                def step(carry, x):
                    bad = float(carry)
                    return carry + bad, carry
                return lax.scan(step, 0.0, xs)
        """)
        assert rules_fired(findings) == {"RPL001"}
        assert "float" in findings[0].message

    def test_python_if_on_traced_value_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from jax import lax

            def kernel(xs):
                def step(carry, x):
                    if carry > 0:
                        carry = carry - 1
                    return carry + x, carry
                return lax.scan(step, 0.0, xs)
        """)
        assert rules_fired(findings) == {"RPL001"}
        assert "`if`" in findings[0].message

    def test_item_and_closure_mutation_fire(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from jax import lax

            trace_log = []

            def kernel(xs):
                def step(carry, x):
                    trace_log.append(x)
                    peek = carry.item()
                    return carry + x, peek
                return lax.scan(step, 0.0, xs)
        """)
        assert rules_fired(findings) == {"RPL001"}
        messages = " ".join(f.message for f in findings)
        assert ".item()" in messages and "trace_log.append" in messages

    def test_while_loop_both_args_and_fori_body_are_traced(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from jax import lax

            def kernel(n, x0):
                def cond(x):
                    return bool(x)
                def body(x):
                    return x - 1
                def fbody(i, acc):
                    return acc + int(i)
                y = lax.while_loop(cond, body, x0)
                return lax.fori_loop(0, n, fbody, y)
        """)
        assert rules_fired(findings) == {"RPL001"}
        assert len(findings) == 2  # bool() in cond, int() in fbody

    def test_clean_scan_body_passes(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import jax.numpy as jnp
            from jax import lax

            def kernel(xs):
                def step(carry, x):
                    nxt = jnp.where(carry > 0, carry - 1.0, carry)
                    return nxt + x, nxt
                return lax.scan(step, 0.0, xs)
        """)
        assert findings == []

    def test_builtin_map_is_not_a_traced_body(self, tmp_path):
        # only lax.map counts — builtin map must not put `f` under taint
        findings = lint_snippet(tmp_path, """
            def host_side(values):
                def f(v):
                    return float(v)
                return list(map(f, values))
        """)
        assert findings == []


# ---------------------------------------------------------------------------
# RPL002 — order-nondeterministic reductions
# ---------------------------------------------------------------------------


class TestNondeterministicReduction:
    def test_sum_over_set_and_dict_values_fire(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            def totals(loads):
                a = sum({1.0, 2.0, 3.0})
                b = sum(loads.values())
                return a + b
        """)
        assert [f.rule for f in findings] == ["RPL002", "RPL002"]

    def test_hash_fed_from_set_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import hashlib

            def digest(parts):
                return hashlib.sha256(str(set(parts)).encode()).hexdigest()
        """)
        assert rules_fired(findings) == {"RPL002"}

    def test_set_iteration_only_flagged_in_artifact_modules(self, tmp_path):
        src = """
            def payload(units):
                return [u for u in set(units)]
        """
        clean = lint_snippet(tmp_path / "a", src, "repro/core/free.py")
        assert clean == []
        flagged = lint_snippet(tmp_path / "b", src, "repro/experiments/cache.py")
        assert rules_fired(flagged) == {"RPL002"}

    def test_minmax_over_dict_values_is_order_deterministic(self, tmp_path):
        # max over float dict values has a well-defined result regardless of
        # iteration order — the real tree relies on this (report/simulator)
        findings = lint_snippet(tmp_path, """
            def peak(link_load):
                return max(link_load.values())

            def sorted_total(link_load):
                return sum(sorted(link_load.values()))
        """)
        assert findings == []


# ---------------------------------------------------------------------------
# RPL003 — dtype discipline
# ---------------------------------------------------------------------------


class TestDtypeDiscipline:
    def test_float32_in_reference_package_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import numpy as np

            def weaken(x):
                a = np.float32(x)
                b = x.astype("float32")
                c = np.zeros(3, dtype="float32")
                return a, b, c
        """, "repro/core/weaken.py")
        assert [f.rule for f in findings] == ["RPL003"] * 3

    def test_float32_outside_reference_packages_is_fine(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import numpy as np

            def accel(x):
                return np.float32(x)
        """, "repro/models/accel.py")
        assert findings == []

    def test_jnp_float64_needs_x64_guard(self, tmp_path):
        bad = lint_snippet(tmp_path / "a", """
            import jax.numpy as jnp

            def f(x):
                return jnp.asarray(x, dtype=jnp.float64)
        """, "repro/models/f64.py")
        assert rules_fired(bad) == {"RPL003"}
        good = lint_snippet(tmp_path / "b", """
            import jax
            import jax.numpy as jnp

            jax.config.update("jax_enable_x64", True)

            def f(x):
                return jnp.asarray(x, dtype=jnp.float64)
        """, "repro/models/f64.py")
        assert good == []

    def test_adhoc_depth_coercion_in_nocsim_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            def schedule(params):
                return float(params.buffer_depth)
        """)
        assert rules_fired(findings) == {"RPL003"}
        assert "normalize_buffer_depth" in findings[0].message

    def test_the_audited_helper_itself_is_exempt(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            def normalize_buffer_depth(depth):
                if depth is None:
                    return float("inf")
                return float(depth)
        """)
        assert findings == []


# ---------------------------------------------------------------------------
# RPL004 — RNG hygiene
# ---------------------------------------------------------------------------


class TestRngHygiene:
    def test_global_state_numpy_rng_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import numpy as np

            def jitter(n):
                np.random.seed(0)
                return np.random.rand(n)
        """, "repro/core/jitter.py")
        assert [f.rule for f in findings] == ["RPL004", "RPL004"]

    def test_stdlib_random_module_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import random

            def pick(xs):
                return random.choice(xs)
        """, "repro/core/pick.py")
        assert rules_fired(findings) == {"RPL004"}

    def test_seeded_generator_passes(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import numpy as np

            def jitter(n, seed):
                rng = np.random.default_rng(seed)
                return rng.random(n)
        """, "repro/core/jitter.py")
        assert findings == []


# ---------------------------------------------------------------------------
# RPL005 — wall-clock/entropy in payloads
# ---------------------------------------------------------------------------


class TestWallClockPayload:
    def test_entropy_banned_everywhere(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import os
            import uuid

            def token():
                return os.urandom(8).hex() + str(uuid.uuid4())
        """, "repro/models/token.py")
        assert [f.rule for f in findings] == ["RPL005", "RPL005"]

    def test_wall_clock_only_flagged_in_payload_modules(self, tmp_path):
        src = """
            import time

            def stamp():
                return time.time()
        """
        clean = lint_snippet(tmp_path / "a", src, "repro/launch/stamp.py")
        assert clean == []
        flagged = lint_snippet(tmp_path / "b", src, "repro/experiments/journal.py")
        assert rules_fired(flagged) == {"RPL005"}

    def test_perf_counter_durations_do_not_trip_wall_clock_payload_rule(self, tmp_path):
        # Durations never reach payloads, so RPL005 stays quiet — but raw
        # clock reads outside repro/obs/ now go through the obs layer
        # (RPL009), which is the only rule that should fire here.
        findings = lint_snippet(tmp_path, """
            import time

            def timed(fn):
                t0 = time.perf_counter()
                out = fn()
                return out, time.perf_counter() - t0
        """, "repro/experiments/cache.py")
        assert rules_fired(findings) == {"RPL009"}


# ---------------------------------------------------------------------------
# RPL009 — raw clock reads outside repro/obs/
# ---------------------------------------------------------------------------


class TestTimingIdiom:
    def test_raw_monotonic_read_flagged(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import time

            def stamp():
                return time.monotonic_ns()
        """, "repro/experiments/sweep.py")
        assert rules_fired(findings) == {"RPL009"}
        assert any("RPL009" == f.rule and f.line == 5 for f in findings)

    def test_obs_package_may_read_clocks(self, tmp_path):
        # repro/obs/ is the one place raw clocks are allowed — it IS the
        # timing layer the rest of the tree is routed through.
        findings = lint_snippet(tmp_path, """
            import time

            def now_ns():
                return time.perf_counter_ns()
        """, "repro/obs/trace.py")
        assert findings == []

    def test_obs_routed_timing_is_compliant(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from repro import obs

            def timed(fn):
                with obs.span("stage") as sp:
                    out = fn()
                return out, sp.duration_s
        """, "repro/experiments/sweep.py")
        assert findings == []

    def test_time_sleep_is_not_a_clock_read(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import time

            def backoff(attempt):
                time.sleep(0.01 * attempt)
        """, "repro/experiments/cache.py")
        assert findings == []


# ---------------------------------------------------------------------------
# RPL006 / RPL008 — parity registration and its resolvability
# ---------------------------------------------------------------------------


def _write_serial_reference(tmp_path):
    ref = tmp_path / "repro" / "core" / "placement.py"
    ref.parent.mkdir(parents=True, exist_ok=True)
    ref.write_text("def greedy_placement(parts, topo):\n    return parts\n")


class TestParityRegistration:
    def test_unregistered_public_batch_kernel_fires(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            def solve_batch(stack):
                return stack
        """, "repro/experiments/solve.py")
        assert rules_fired(findings) == {"RPL006"}
        assert "solve_batch" in findings[0].message

    def test_private_and_out_of_scope_kernels_are_exempt(self, tmp_path):
        findings = lint_snippet(
            tmp_path / "a", "def _solve_batch(s):\n    return s\n",
            "repro/experiments/solve.py",
        ) + lint_snippet(
            tmp_path / "b", "def pack_batch(s):\n    return s\n",
            "repro/models/packing.py",
        )
        assert findings == []

    def test_registered_kernel_with_resolvable_serial_passes(self, tmp_path):
        _write_serial_reference(tmp_path)
        findings = lint_snippet(tmp_path, """
            from repro.analysis.registry import parity_pair

            @parity_pair(serial="repro.core.placement.greedy_placement", kind="bit")
            def solve_batch(stack):
                return stack
        """, "repro/experiments/solve.py")
        assert findings == []

    def test_unresolvable_serial_path_fires_rpl008(self, tmp_path):
        _write_serial_reference(tmp_path)
        findings = lint_snippet(tmp_path, """
            from repro.analysis.registry import parity_pair

            @parity_pair(serial="repro.core.placement.renamed_away", kind="bit")
            def solve_batch(stack):
                return stack
        """, "repro/experiments/solve.py")
        assert rules_fired(findings) == {"RPL008"}
        assert "renamed_away" in findings[0].message

    def test_bad_kind_and_nonliteral_serial_fire_rpl008(self, tmp_path):
        _write_serial_reference(tmp_path)
        findings = lint_snippet(tmp_path, """
            from repro.analysis.registry import parity_pair

            TARGET = "repro.core.placement.greedy_placement"

            @parity_pair(serial=TARGET, kind="exact")
            def solve_batch(stack):
                return stack
        """, "repro/experiments/solve.py")
        assert [f.rule for f in findings] == ["RPL008", "RPL008"]

    def test_bare_decorator_without_call_fires_rpl008(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            from repro.analysis.registry import parity_pair

            @parity_pair
            def solve_batch(stack):
                return stack
        """, "repro/experiments/solve.py")
        assert rules_fired(findings) == {"RPL008"}


# ---------------------------------------------------------------------------
# RPL007 — suppressions: round trip, malformed, stale
# ---------------------------------------------------------------------------


class TestSuppressions:
    BAD = """
        import numpy as np

        def jitter(n):
            return np.random.rand(n){directive}
    """

    def test_reasoned_suppression_silences_the_finding(self, tmp_path):
        findings = lint_snippet(
            tmp_path,
            self.BAD.format(
                directive="  # repro-lint: disable=RPL004 perf probe, seed irrelevant"
            ),
            "repro/core/jitter.py",
        )
        assert findings == []

    def test_suppression_on_comment_line_above_also_applies(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            import numpy as np

            def jitter(n):
                # repro-lint: disable=RPL004 perf probe, seed irrelevant
                return np.random.rand(n)
        """, "repro/core/jitter.py")
        assert findings == []

    def test_missing_reason_is_malformed_and_does_not_suppress(self, tmp_path):
        findings = lint_snippet(
            tmp_path,
            self.BAD.format(directive="  # repro-lint: disable=RPL004"),
            "repro/core/jitter.py",
        )
        assert rules_fired(findings) == {"RPL004", "RPL007"}

    def test_unknown_rule_id_is_flagged(self, tmp_path):
        findings = lint_snippet(
            tmp_path,
            self.BAD.format(directive="  # repro-lint: disable=RPL999 because"),
            "repro/core/jitter.py",
        )
        assert rules_fired(findings) == {"RPL004", "RPL007"}

    def test_stale_suppression_is_flagged(self, tmp_path):
        findings = lint_snippet(tmp_path, """
            def fine():  # repro-lint: disable=RPL004 nothing here draws randomness
                return 1
        """, "repro/core/fine.py")
        assert rules_fired(findings) == {"RPL007"}
        assert "stale" in findings[0].message

    def test_docstring_mentioning_grammar_is_not_a_directive(self, tmp_path):
        # regression: only tokenize COMMENT tokens parse as directives
        findings = lint_snippet(tmp_path, '''
            """Suppress with `# repro-lint: disable=RPL001 <reason>` inline."""

            def fine():
                return 1
        ''', "repro/core/doc.py")
        assert findings == []


# ---------------------------------------------------------------------------
# engine: baseline lifecycle + syntax errors
# ---------------------------------------------------------------------------


class TestBaseline:
    def _finding(self, msg="m1"):
        return Finding(path="repro/core/x.py", line=3, col=1,
                       rule="RPL004", message=msg)

    def test_round_trip_and_shrink_only_diff(self, tmp_path):
        path = tmp_path / "baseline.json"
        grandfathered = [self._finding("old"), self._finding("old")]
        write_baseline(str(path), grandfathered)
        baseline = load_baseline(str(path))

        ok = diff_vs_baseline(grandfathered, baseline)
        assert ok.ok

        regressed = diff_vs_baseline(
            grandfathered + [self._finding("new")], baseline
        )
        assert [f.message for f in regressed.new] == ["new"]

        fixed = diff_vs_baseline([self._finding("old")], baseline)
        assert not fixed.ok and fixed.stale[0]["count"] == 1

    def test_missing_baseline_is_empty(self, tmp_path):
        assert load_baseline(str(tmp_path / "absent.json")) == {}

    def test_unsupported_version_rejected(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps({"version": 99, "findings": []}))
        with pytest.raises(ValueError, match="version"):
            load_baseline(str(path))

    def test_syntax_error_is_a_finding_not_a_crash(self, tmp_path):
        findings = lint_snippet(tmp_path, "def broken(:\n", "repro/core/bad.py")
        assert rules_fired(findings) == {"RPL000"}


# ---------------------------------------------------------------------------
# registry + generated parity table
# ---------------------------------------------------------------------------


class TestRegistry:
    # the five pairs of the historical hand-maintained ARCHITECTURE table
    HISTORICAL_PAIRS = {
        "repro.experiments.placement_batch.greedy_construct_batch":
            "repro.core.placement.greedy_placement",
        "repro.experiments.placement_batch.torus_construct_batch":
            "repro.core.placement.torus_quad_placement",
        "repro.experiments.placement_batch.batch_descend":
            "repro.core.placement.two_opt_best_move",
        "repro.experiments.batched.simulate_batch":
            "repro.core.simulator.simulate",
        "repro.nocsim.batch.contended_batch":
            "repro.nocsim.model.simulate_contended",
    }

    def test_all_historical_architecture_pairs_are_registered(self):
        registry = load_registry()
        for batched, serial in self.HISTORICAL_PAIRS.items():
            assert batched in registry, f"{batched} lost its @parity_pair"
            assert registry[batched].serial == serial
            assert registry[batched].kind in PARITY_KINDS

    def test_decorator_is_zero_cost_and_validates_inputs(self):
        from repro.analysis import registry as reg

        @parity_pair(serial="repro.core.placement.greedy_placement", kind="bit")
        def probe_batch(x):
            return x + 1

        # the registry is process-global — drop the probe so the parity
        # table rendered by later tests stays the committed one
        reg._REGISTRY.pop(probe_batch.__parity_pair__.batched)
        assert probe_batch(1) == 2
        assert probe_batch.__parity_pair__.kind == "bit"
        with pytest.raises(ValueError, match="kind"):
            parity_pair(serial="repro.core.x.y", kind="exact")
        with pytest.raises(ValueError, match="dotted"):
            parity_pair(serial="bare", kind="bit")


class TestParityTable:
    FAKE = {
        "repro.pkg.b_batch": ParityEntry(
            batched="repro.pkg.b_batch", serial="repro.core.b", kind="bit",
            note="same tie-breaks",
        ),
        "repro.pkg.a_batch": ParityEntry(
            batched="repro.pkg.a_batch", serial="repro.core.a", kind="rel",
            tol=1e-5,
        ),
    }

    def test_render_sorts_rows_and_formats_contracts(self):
        table = parity_table.render_parity_table(self.FAKE)
        lines = table.splitlines()
        assert lines[0].startswith("| batched kernel ")
        assert "`repro.pkg.a_batch`" in lines[2] and "within 1e-05 relative" in lines[2]
        assert "`repro.pkg.b_batch`" in lines[3]
        assert "**bit-identical** (numpy backend) — same tie-breaks" in lines[3]

    def test_committed_table_is_fresh(self):
        doc = str(REPO_ROOT / "docs" / "ARCHITECTURE.md")
        assert parity_table.main(["--check", "--doc", doc]) == 0

    def test_check_fails_on_stale_doc_and_missing_markers(self, tmp_path, capsys):
        doc = tmp_path / "ARCH.md"
        doc.write_text(
            f"intro\n{parity_table.MARK_BEGIN}\nstale rows\n{parity_table.MARK_END}\nout\n"
        )
        assert parity_table.main(["--check", "--doc", str(doc)]) == 1
        assert "STALE" in capsys.readouterr().err

        assert parity_table.main(["--doc", str(doc)]) == 0  # regenerate…
        assert parity_table.main(["--check", "--doc", str(doc)]) == 0  # …fresh

        bare = tmp_path / "bare.md"
        bare.write_text("no markers here\n")
        assert parity_table.main(["--check", "--doc", str(bare)]) == 2


# ---------------------------------------------------------------------------
# the real tree + the ISSUE acceptance mutations
# ---------------------------------------------------------------------------


def _copy_repro_tree(tmp_path):
    dst = tmp_path / "repro"
    shutil.copytree(SRC / "repro", dst,
                    ignore=shutil.ignore_patterns("__pycache__"))
    return dst


class TestRealTree:
    def test_src_lints_clean_against_committed_baseline(self):
        rc = lint_main([str(SRC), "--check-baseline", "--baseline", str(BASELINE)])
        assert rc == 0

    def test_committed_baseline_is_empty(self):
        payload = json.loads(BASELINE.read_text())
        assert payload == {"version": 1, "findings": []}

    def test_every_rule_has_id_and_title(self):
        catalog = rule_catalog()
        assert len(catalog) == len(ALL_RULES) == 9
        assert all(rid.startswith("RPL") for rid in catalog)

    def test_deleting_a_parity_pair_decorator_trips_rpl006(self, tmp_path):
        tree = _copy_repro_tree(tmp_path)
        target = tree / "experiments" / "placement_batch.py"
        text = target.read_text()
        idx_def = text.index("def repair_batch(")
        idx_dec = text.rindex("@parity_pair(", 0, idx_def)
        target.write_text(text[:idx_dec] + text[idx_def:])

        findings = lint_paths([str(tmp_path)]).findings
        assert [f.rule for f in findings] == ["RPL006"]
        assert "repair_batch" in findings[0].message

    def test_injecting_float_tracer_into_scan_body_trips_rpl001(self, tmp_path):
        tree = _copy_repro_tree(tmp_path)
        target = tree / "nocsim" / "batch.py"
        text = target.read_text()
        anchor = "            arrived = backlog + injected\n"
        assert anchor in text
        target.write_text(text.replace(
            anchor, anchor + "            leak = float(backlog)\n", 1
        ))

        findings = lint_paths([str(tmp_path)]).findings
        assert [f.rule for f in findings] == ["RPL001"]
        assert "float" in findings[0].message

    def test_cli_json_format_and_list_rules(self, tmp_path, capsys):
        bad = tmp_path / "repro" / "core" / "bad.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("import numpy as np\nx = np.random.rand(3)\n")
        rc = lint_main([str(tmp_path), "--format", "json"])
        out = json.loads(capsys.readouterr().out)
        assert rc == 1 and not out["ok"]
        assert out["findings"][0]["rule"] == "RPL004"

        assert lint_main(["--list-rules"]) == 0
        listed = capsys.readouterr().out
        assert "RPL001" in listed and "RPL008" in listed
