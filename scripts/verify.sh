#!/usr/bin/env bash
# CI entry point: the FULL tier-1 suite as the gate, the EXPERIMENTS.md
# freshness audit, a 3-config mini-sweep through the full trace → partition →
# place (batched quad + greedy construction) → batched-simulate → report
# pipeline, and the resumable dry-run artifact sweep.
#
# The whole suite gates: the last 5 seed failures (roofline HLO parse,
# elastic reshard restore, the 3 multi-device subprocess meshes) were fixed
# by the jax-0.4 compat shims (src/repro/compat.py), so there is no
# "pre-existing failures" carve-out any more.  Property tests never skip:
# tests/_hypothesis_compat.py vendors a minimal fallback runner when the
# offline container has no hypothesis wheel.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== test extras (hypothesis for the property tests) =="
if python -c "import hypothesis" 2>/dev/null; then
    echo "hypothesis already installed"
elif pip install -q "hypothesis>=6" 2>/dev/null || pip install -q -e ".[test]" 2>/dev/null; then
    echo "installed hypothesis via the [test] extra"
else
    echo "hypothesis unavailable (offline container without a wheel);"
    echo "property tests run on the vendored fallback (tests/_hypothesis_compat.py)"
fi

echo "== gating tests (full tier-1 suite) =="
python -m pytest -x -q

echo "== EXPERIMENTS.md freshness vs committed payloads =="
python -m repro.experiments.report --check

echo "== mini sweep (3 configs) =="
out="$(mktemp -d)"
python -m repro.experiments.run --grid mini \
    --md "$out/EXPERIMENTS.mini.md" --json "$out/BENCH_sweep.mini.json" \
    --cache-dir "$out/cache" --sweeps-dir "$out/sweeps"
test -s "$out/EXPERIMENTS.mini.md"
test -s "$out/BENCH_sweep.mini.json"
python - "$out/BENCH_sweep.mini.json" <<'EOF'
import json, sys
payload = json.load(open(sys.argv[1]))
assert payload["records"], "mini sweep produced no records"
assert payload["comparisons"], "mini sweep produced no comparisons"
for c in payload["comparisons"]:
    assert c["speedup"] > 1.0 and c["hop_decrease"] > 1.0, c
ps = payload["placement_stats"]
assert ps["batched_configs"] >= 2, f"batched placement path not exercised: {ps}"
assert ps["greedy_constructed"] >= 1, f"batched greedy construction not exercised: {ps}"
assert ps["h_worse_than_serial_configs"] == 0, f"batched H worse than serial: {ps}"
assert any(
    "2opt[batch]" in r["placement_method"] for r in payload["records"]
), "no record carries the batched-engine method tag"
assert any(
    r["placement_method"] == "greedy+2opt[batch]" for r in payload["records"]
), "no record went through the stacked greedy construction"
c = payload["comparisons"][0]
print(f"mini sweep ok: speedup={c['speedup']:.2f}x hop_decrease={c['hop_decrease']:.2f}x "
      f"placement batched={ps['batched_configs']} greedy-constructed="
      f"{ps['greedy_constructed']} (H ratio max {ps['h_vs_serial_max_ratio']:.4f})")
EOF
rm -rf "$out"

echo "== dry-run artifacts (§Dry-run / §Roofline) =="
# Resumable: committed artifacts/dryrun/*.json cells are read back, only
# missing/failed cells recompile (minutes each on an empty dir).  Offline- and
# jax-version-tolerant: a failing sweep downgrades to a warning — the report
# still renders from whatever records are committed.
if [[ "${VERIFY_SKIP_DRYRUN:-0}" == "1" ]]; then
    echo "skipped (VERIFY_SKIP_DRYRUN=1)"
elif python -m repro.launch.dryrun --all --out artifacts/dryrun; then
    echo "dry-run records complete (artifacts/dryrun)"
else
    echo "WARNING: dry-run sweep incomplete on this container; §Dry-run/"
    echo "         §Roofline render from the committed artifacts/dryrun records"
fi
if [[ "${VERIFY_SKIP_DRYRUN:-0}" != "1" ]]; then
    # artifacts/dryrun is version-controlled evidence: keep only status=ok
    # digests in it (a failing cell's traceback record must not be commit
    # bait; the resumable sweep retries non-ok cells anyway).
    python - <<'EOF'
import glob, json, os
for f in glob.glob("artifacts/dryrun/*.json"):
    if json.load(open(f)).get("status") != "ok":
        os.remove(f)
        print(f"removed failed dry-run record {f} (kept out of the evidence dir)")
EOF
fi

echo "VERIFY OK"
