#!/usr/bin/env bash
# CI entry point: the FULL tier-1 suite as the gate, the EXPERIMENTS.md
# freshness audit, a 3-config mini-sweep through the full trace → partition →
# place (batched quad + greedy construction) → batched-simulate → report
# pipeline, the observability arm (trace/metrics schema validation,
# recording-on ≡ recording-off byte-identity, <5% overhead gate), the
# resilience and backpressure mini-grids (degraded and credit nocsim arms
# end to end), a gated nocsim coverage floor, and the resumable dry-run
# artifact sweep.
#
# The whole suite gates: the last 5 seed failures (roofline HLO parse,
# elastic reshard restore, the 3 multi-device subprocess meshes) were fixed
# by the jax-0.4 compat shims (src/repro/compat.py), so there is no
# "pre-existing failures" carve-out any more.  Property tests never skip:
# tests/_hypothesis_compat.py vendors a minimal fallback runner when the
# offline container has no hypothesis wheel.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== test extras (hypothesis for the property tests) =="
if python -c "import hypothesis" 2>/dev/null; then
    echo "hypothesis already installed"
elif pip install -q "hypothesis>=6" 2>/dev/null || pip install -q -e ".[test]" 2>/dev/null; then
    echo "installed hypothesis via the [test] extra"
else
    echo "hypothesis unavailable (offline container without a wheel);"
    echo "property tests run on the vendored fallback (tests/_hypothesis_compat.py)"
fi

echo "== gating tests (full tier-1 suite) =="
python -m pytest -x -q

echo "== jax >= 0.5 native-API arm (compat shims force-disabled) =="
# ROADMAP jax-version matrix: when the installed jax already provides the
# 0.5 surface natively (AxisType / set_mesh / shard_map / make_mesh
# axis_types), re-run a fast smoke subset with install_jax05_compat()
# force-disabled so the no-op branch of every shim is exercised against the
# real APIs.  On the pinned 0.4 container the arm is skipped — there the
# shims themselves are what the full suite above just exercised — keeping
# both branches honest whichever jax the image ships.
if python - <<'EOF'
import inspect, sys
try:
    import jax
except ImportError:
    sys.exit(1)
native = (
    hasattr(jax, "set_mesh")
    and hasattr(jax, "shard_map")
    and hasattr(jax.sharding, "AxisType")
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)
sys.exit(0 if native else 1)
EOF
then
    REPRO_DISABLE_JAX05_COMPAT=1 python -m pytest -q \
        tests/test_nocsim.py tests/test_simulator_and_traffic.py \
        tests/test_placement_batch.py tests/test_models.py
else
    echo "installed jax lacks the native 0.5 surface; smoke arm skipped"
    echo "(the 0.4->0.5 shims were exercised by the full suite above)"
fi

echo "== scale memory budget (sparse pipeline @ soc-pokec scale 0.1) =="
# The published-size pipeline guard: one scale-0.1 soc-pokec sweep (3.06M
# edges) under a peak-RSS assertion (tests/test_scale_memory.py, 2 GiB
# budget vs ~1 GiB measured).  Marked `slow` + env-gated so tier-1 above
# stays fast; VERIFY_SKIP_SCALE_RSS=1 skips it on constrained containers.
if [[ "${VERIFY_SKIP_SCALE_RSS:-0}" == "1" ]]; then
    echo "skipped (VERIFY_SKIP_SCALE_RSS=1)"
else
    REPRO_SCALE_RSS=1 python -m pytest -q tests/test_scale_memory.py
fi

echo "== EXPERIMENTS.md freshness vs committed payloads =="
python -m repro.experiments.report --check

echo "== parity/determinism contract lint =="
# Pure-local AST pass: fails on any finding not grandfathered in
# artifacts/lint_baseline.json (and on stale baseline entries — the
# baseline is shrink-only), then asserts the ARCHITECTURE.md parity table
# still matches the @parity_pair registry.
python -m repro.analysis.lint src --check-baseline
python -m repro.analysis.parity_table --check

echo "== mini sweep (3 configs) =="
out="$(mktemp -d)"
python -m repro.experiments.run --grid mini \
    --md "$out/EXPERIMENTS.mini.md" --json "$out/BENCH_sweep.mini.json" \
    --cache-dir "$out/cache" --sweeps-dir "$out/sweeps"
test -s "$out/EXPERIMENTS.mini.md"
test -s "$out/BENCH_sweep.mini.json"
python - "$out/BENCH_sweep.mini.json" <<'EOF'
import json, sys
payload = json.load(open(sys.argv[1]))
assert payload["records"], "mini sweep produced no records"
assert payload["comparisons"], "mini sweep produced no comparisons"
for c in payload["comparisons"]:
    assert c["speedup"] > 1.0 and c["hop_decrease"] > 1.0, c
ps = payload["placement_stats"]
assert ps["batched_configs"] >= 2, f"batched placement path not exercised: {ps}"
assert ps["greedy_constructed"] >= 1, f"batched greedy construction not exercised: {ps}"
assert ps["h_worse_than_serial_configs"] == 0, f"batched H worse than serial: {ps}"
assert any(
    "2opt[batch]" in r["placement_method"] for r in payload["records"]
), "no record carries the batched-engine method tag"
assert any(
    r["placement_method"] == "greedy+2opt[batch]" for r in payload["records"]
), "no record went through the stacked greedy construction"
c = payload["comparisons"][0]
print(f"mini sweep ok: speedup={c['speedup']:.2f}x hop_decrease={c['hop_decrease']:.2f}x "
      f"placement batched={ps['batched_configs']} greedy-constructed="
      f"{ps['greedy_constructed']} (H ratio max {ps['h_vs_serial_max_ratio']:.4f})")
EOF
rm -rf "$out"

echo "== observability arm (trace/metrics on the mini grid) =="
# Flight-recorder contract: --trace-out/--metrics-out produce schema-valid
# Chrome-trace + metrics JSON, recording on vs off leaves the rendered
# artifacts byte-identical (deterministic clock), and the all-in wall-clock
# overhead of tracing stays under 5%.
oout="$(mktemp -d)"
python -m repro.experiments.run --grid mini -q --cache-dir "$oout/cache" \
    --md "$oout/warm.md" --json "$oout/warm.json"   # warm the sweep cache
REPRO_OBS_DETERMINISTIC=1 python -m repro.experiments.run --grid mini -q \
    --cache-dir "$oout/cache" --md "$oout/off.md" --json "$oout/off.json"
REPRO_OBS_DETERMINISTIC=1 python -m repro.experiments.run --grid mini -q \
    --cache-dir "$oout/cache" --md "$oout/on.md" --json "$oout/on.json" \
    --trace-out "$oout/trace.json" --metrics-out "$oout/metrics.json"
cmp "$oout/off.md" "$oout/on.md"
cmp "$oout/off.json" "$oout/on.json"
echo "recording on vs off: rendered artifacts byte-identical"
python -m repro.obs.validate "$oout/trace.json" --schema schemas/trace.schema.json
python -m repro.obs.validate "$oout/metrics.json" --schema schemas/metrics.schema.json
python - "$oout/trace.json" "$oout/metrics.json" <<'EOF'
import json, os, sys
trace = json.load(open(sys.argv[1]))
spans = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
assert "pipeline.sweep" in spans and "sweep.placement" in spans, sorted(spans)
counters = [e for e in trace["traceEvents"] if e["ph"] == "C"]
assert counters, "no per-link counter tracks in the trace"
assert trace["otherData"]["dropped_spans"] == 0, trace["otherData"]
heat = json.load(open(os.path.splitext(sys.argv[1])[0] + ".heatmap.json"))
assert heat["tracks"], "heatmap artifact has no tracks"
snap = json.load(open(sys.argv[2]))
stages = snap["non_comparable"]["sweep.stage_seconds"]["series"]
assert any(s["labels"]["stage"] == "placement" for s in stages), stages
tracks = {(e["pid"], e["name"]) for e in counters}
print(f"obs arm ok: {len(spans)} span names, {len(tracks)} counter tracks,"
      f" {len(heat['tracks'])} heatmap tracks")
EOF
# Overhead gate: tracing + flight recording must cost <5% of an untraced
# end-to-end mini run.  The two sides are measured separately because they
# need different precision: the NUMERATOR (traced-minus-untraced CPU) is a
# ~15-20ms signal that end-to-end subprocess timings cannot resolve — cold
# interpreter + import CPU jitters by ±50ms run to run — so it is measured
# in-process on a warm cache as the median of order-alternated paired reps
# (imports and cache warmup cancel exactly; CPU time via getrusage, immune
# to wall-clock scheduling noise).  The DENOMINATOR (untraced full-run
# cost) only needs ~5% precision, so a median of 3 cold child-CPU runs is
# plenty.
python - "$oout" <<'EOF'
import os, resource, statistics, subprocess, sys
out = sys.argv[1]
argv = ["--grid", "mini", "-q", "--cache-dir", os.path.join(out, "cache"),
        "--md", os.path.join(out, "t.md"), "--json", os.path.join(out, "t.json")]
traced_extra = ["--trace-out", os.path.join(out, "t.trace.json"),
                "--metrics-out", os.path.join(out, "t.metrics.json")]
cold_cmd = [sys.executable, "-m", "repro.experiments.run"] + argv
def cold():
    r0 = resource.getrusage(resource.RUSAGE_CHILDREN)
    subprocess.run(cold_cmd, check=True, capture_output=True)
    r1 = resource.getrusage(resource.RUSAGE_CHILDREN)
    return (r1.ru_utime + r1.ru_stime) - (r0.ru_utime + r0.ru_stime)
cold()  # warm the sweep cache
denom = statistics.median(cold() for _ in range(3))
from repro import obs
from repro.experiments.run import main
def rep(extra):
    r0 = resource.getrusage(resource.RUSAGE_SELF)
    main(argv + extra)
    r1 = resource.getrusage(resource.RUSAGE_SELF)
    obs.disable_tracing()
    obs.get_tracer().reset()
    return (r1.ru_utime + r1.ru_stime) - (r0.ru_utime + r0.ru_stime)
rep([]); rep(traced_extra)  # warm both paths
diffs = []
for i in range(7):
    if i % 2 == 0:
        p = rep([]); t = rep(traced_extra)
    else:
        t = rep(traced_extra); p = rep([])
    diffs.append(t - p)
num = statistics.median(diffs)
overhead = num / denom * 100.0
assert overhead < 5.0, (
    f"tracing overhead {overhead:.1f}% >= 5%"
    f" ({num*1e3:.1f}ms added to a {denom*1e3:.0f}ms untraced run)"
)
print(f"obs overhead ok: +{overhead:.1f}% ({num*1e3:.1f}ms obs cost,"
      f" median of 7 paired reps, vs {denom*1e3:.0f}ms untraced run)")
EOF
rm -rf "$oout"

echo "== resilience arm (mini faults grid + crash-resume smoke) =="
# Degraded-fabric pipeline end to end: the 2-unit minifaults grid through
# FaultSet -> detour routing -> degraded nocsim (jax parity when available)
# -> evacuation/repair, then a literal kill -9 mid-sweep with a journaled
# --resume that must reproduce the uninterrupted artifact byte for byte.
rout="$(mktemp -d)"
python -m repro.experiments.run --grid minifaults --backend auto -q \
    --cache-dir "$rout/cache" --sweeps-dir "$rout/a" --journal "$rout/a.journal.json"
python - "$rout/a/minifaults.json" <<'EOF'
import json, sys
payload = json.load(open(sys.argv[1]))["faults"]
recs = payload["records"]
assert recs, "minifaults produced no unit records"
rates = {r["fault_rate"] for r in recs}
assert rates == {0.0, 0.05}, f"unexpected fault rates {rates}"
clean = next(r for r in recs if r["fault_rate"] == 0.0)
faulted = next(r for r in recs if r["fault_rate"] == 0.05)
assert clean["win"] > 1.0, f"proposed scheme does not win on the clean fabric: {clean['win']}"
assert faulted["num_dead_links"] > 0 and faulted["num_detoured_flows"] > 0, faulted
assert payload["repair"], "no repair-ledger rows"
for row in payload["repair"]:
    assert row["batch_parity"], f"repair serial/batched mismatch: {row}"
    assert row["h_repaired"] <= row["h_evacuated"] + 1e-9, row
assert not payload["quarantined"], f"quarantined units: {payload['quarantined']}"
parity = payload["backend_parity_max_rel"]
if parity is not None:  # jax was available -> the degraded arm ran both backends
    assert parity <= payload["parity_rtol"], f"degraded-arm parity {parity:.3e}"
    print(f"resilience ok: win {clean['win']:.2f}x -> {faulted['win']:.2f}x at 5% faults;"
          f" jax parity {parity:.2e} <= {payload['parity_rtol']:g}")
else:
    print(f"resilience ok: win {clean['win']:.2f}x -> {faulted['win']:.2f}x at 5% faults;"
          " jax absent, numpy-only")
EOF
# Crash-resume smoke: kill -9 between journal flushes, resume, compare bytes.
REPRO_FAULTS_UNIT_DELAY=2.0 python -m repro.experiments.run --grid minifaults \
    --backend auto -q --cache-dir "$rout/cache" --sweeps-dir "$rout/b" \
    --journal "$rout/b.journal.json" &
victim=$!
for _ in $(seq 1 200); do
    python - "$rout/b.journal.json" <<'EOF' && break
import json, sys
try:
    raise SystemExit(0 if json.load(open(sys.argv[1])).get("units") else 1)
except (FileNotFoundError, json.JSONDecodeError):
    raise SystemExit(1)
EOF
    sleep 0.1
done
kill -9 "$victim" 2>/dev/null || true
wait "$victim" 2>/dev/null || true
python -m repro.experiments.run --grid minifaults --backend auto -q --resume \
    --cache-dir "$rout/cache" --sweeps-dir "$rout/b" --journal "$rout/b.journal.json"
cmp "$rout/a/minifaults.json" "$rout/b/minifaults.json"
echo "crash-resume smoke ok: resumed artifact is byte-identical"
rm -rf "$rout"

echo "== backpressure arm (minicredit grid: credit flow control end to end) =="
# Closed-loop credit arm through the sweep pipeline: the 2-config minicredit
# grid runs the open + credit(d=1,4) record sets, the infinite-credit
# convergence audit (numpy bit-exact, jax within parity), and the dual
# backends over the identical stacked programs.
bout="$(mktemp -d)"
# minicredit is a CI-only grid (no EXPERIMENTS.md section), so it stores no
# artifacts/sweeps entry; --json captures its machine-readable payload.
python -m repro.experiments.run --grid minicredit --backend auto -q \
    --cache-dir "$bout/cache" --sweeps-dir "$bout/sweeps" \
    --json "$bout/minicredit.json"
python - "$bout/minicredit.json" <<'EOF'
import json, sys
payload = json.load(open(sys.argv[1]))["contention"]
recs = payload["records"]
assert recs, "minicredit produced no contended records"
depths = {r["buffer_depth"] for r in recs if r["flow_control"] == "credit"}
assert depths == {1.0, 4.0}, f"unexpected credit depth axis {depths}"
n_open = sum(r["flow_control"] == "open" for r in recs)
n_credit = sum(r["flow_control"] == "credit" for r in recs)
assert n_open > 0 and n_credit == 2 * n_open, (n_open, n_credit)
inf_np = payload["credit_inf_numpy_max_abs"]
assert inf_np == 0.0, f"infinite-credit numpy audit not bit-exact: {inf_np}"
rtol = payload["parity_rtol"]
parity = payload["backend_parity_max_rel"]
inf_jax = payload["credit_inf_jax_max_rel"]
if parity is not None:  # jax available -> both backends ran every arm
    assert parity <= rtol, f"credit-arm parity {parity:.3e} > {rtol:g}"
    assert inf_jax is not None and inf_jax <= rtol, f"inf-credit jax {inf_jax}"
    print(f"backpressure ok: {n_credit} credit records over depths {sorted(depths)};"
          f" inf-credit numpy exact, jax {inf_jax:.2e}; parity {parity:.2e}")
else:
    print(f"backpressure ok: {n_credit} credit records over depths {sorted(depths)};"
          " inf-credit numpy exact; jax absent, numpy-only")
EOF
rm -rf "$bout"

echo "== nocsim line coverage (property/differential suites vs the steppers) =="
# The conservation-law harness claims to exercise every stepper arm; hold it
# to that with a line-coverage floor over repro.nocsim when pytest-cov is
# importable.  The offline container has no pytest-cov wheel — skip with a
# note rather than fail (the suites themselves gated in tier-1 above).
if python -c "import pytest_cov" 2>/dev/null; then
    python -m pytest -q --cov=repro.nocsim --cov-fail-under=90 \
        tests/test_nocsim.py tests/test_nocsim_invariants.py \
        tests/test_nocsim_differential.py tests/test_golden_regression.py
else
    echo "pytest-cov unavailable (offline container without a wheel);"
    echo "coverage floor skipped — the nocsim suites ran uninstrumented in tier-1"
fi

echo "== dry-run artifacts (§Dry-run / §Roofline) =="
# Resumable: committed artifacts/dryrun/*.json cells are read back, only
# missing/failed cells recompile (minutes each on an empty dir).  Offline- and
# jax-version-tolerant: a failing sweep downgrades to a warning — the report
# still renders from whatever records are committed.
if [[ "${VERIFY_SKIP_DRYRUN:-0}" == "1" ]]; then
    echo "skipped (VERIFY_SKIP_DRYRUN=1)"
elif python -m repro.launch.dryrun --all --out artifacts/dryrun; then
    echo "dry-run records complete (artifacts/dryrun)"
else
    echo "WARNING: dry-run sweep incomplete on this container; §Dry-run/"
    echo "         §Roofline render from the committed artifacts/dryrun records"
fi
if [[ "${VERIFY_SKIP_DRYRUN:-0}" != "1" ]]; then
    # artifacts/dryrun is version-controlled evidence: keep only status=ok
    # digests in it (a failing cell's traceback record must not be commit
    # bait; the resumable sweep retries non-ok cells anyway).
    python - <<'EOF'
import glob, json, os
for f in glob.glob("artifacts/dryrun/*.json"):
    if json.load(open(f)).get("status") != "ok":
        os.remove(f)
        print(f"removed failed dry-run record {f} (kept out of the evidence dir)")
EOF
fi

echo "VERIFY OK"
