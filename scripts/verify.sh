#!/usr/bin/env bash
# CI entry point: gating tests + a 2-config mini-sweep through the full
# trace → partition → place → batched-simulate → report pipeline.
#
# The gate covers the paper-core + experiments suites, which are green.
# The arch/models/distributed suites have known seed failures (tracked in
# ROADMAP.md); run the whole tier-1 suite non-gating with VERIFY_FULL=1.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== gating tests (paper core + experiments) =="
python -m pytest -x -q \
    tests/test_core_partition.py \
    tests/test_core_placement.py \
    tests/test_simulator_and_traffic.py \
    tests/test_graph_algorithms.py \
    tests/test_kernels.py \
    tests/test_experiments_sweep.py

if [[ "${VERIFY_FULL:-0}" == "1" ]]; then
    echo "== full tier-1 suite (non-gating; seed failures tracked in ROADMAP.md) =="
    python -m pytest -q || true
fi

echo "== mini sweep (2 configs) =="
out="$(mktemp -d)"
python -m repro.experiments.run --grid mini \
    --md "$out/EXPERIMENTS.mini.md" --json "$out/BENCH_sweep.mini.json" \
    --cache-dir "$out/cache"
test -s "$out/EXPERIMENTS.mini.md"
test -s "$out/BENCH_sweep.mini.json"
python - "$out/BENCH_sweep.mini.json" <<'EOF'
import json, sys
payload = json.load(open(sys.argv[1]))
assert payload["records"], "mini sweep produced no records"
assert payload["comparisons"], "mini sweep produced no comparisons"
c = payload["comparisons"][0]
assert c["speedup"] > 1.0 and c["hop_decrease"] > 1.0, c
print(f"mini sweep ok: speedup={c['speedup']:.2f}x hop_decrease={c['hop_decrease']:.2f}x")
EOF
rm -rf "$out"
echo "VERIFY OK"
