"""Fig. 8: energy-consumption reduction of the proposed mapping."""
from repro.core.mapping import map_graph
from repro.core.noc import FlattenedButterfly
from repro.core.placement import auto_mesh_for_parts

from benchmarks.common import ALGS, emit, timed, traced, workloads

PARTS = 16


def run():
    m = auto_mesh_for_parts(PARTS)
    topos = {"mesh2d": m, "fbutterfly": FlattenedButterfly(m.kx, m.ky)}
    for gname in workloads():
        for alg in ALGS:
            g, tr = traced(gname, alg)
            for tname, topo in topos.items():
                def compare_once():
                    opt = map_graph(g.src, g.dst, g.num_nodes, PARTS, topology=topo,
                                    edge_activity=tr.edge_activity)
                    base = map_graph(g.src, g.dst, g.num_nodes, PARTS, topology=topo,
                                     partitioner="random", placement_method="random",
                                     edge_activity=tr.edge_activity)
                    return opt.compare_to(base, num_iterations=tr.num_iterations)

                res, us = timed(compare_once, repeats=1)
                emit(
                    f"fig8_energy/{gname}/{alg}/{tname}", us,
                    f"energy_ratio={res['energy_ratio']:.2f}x",
                )
