"""Fig. 8: energy-consumption reduction of the proposed mapping.
Thin adapter over the shared sweep's proposed-vs-baseline comparisons."""
from repro.experiments.sweep import figure_comparisons

from benchmarks.common import emit, paper_sweep


def run():
    sweep = paper_sweep()
    for c in figure_comparisons(sweep.records):
        emit(
            f"fig8_energy/{c['workload']}/{c['algorithm']}/{c['topology']}",
            c["elapsed_us"],
            f"energy_ratio={c['energy_ratio']:.2f}x",
        )
