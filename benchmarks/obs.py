"""§Observability rows: what the obs layer itself costs.

  * obs/span_{disabled,enabled} — per-span cost with the tracer off (the
    steady-state price every pipeline stage pays: two clock reads) and on
    (clock reads + a locked buffer append).
  * obs/sweep_traced — the warm-cache mini sweep with tracing AND the NoC
    flight recorder attached vs the plain run; `derived` carries the
    overhead percentage.  The recorder re-runs the routing arms on the
    numpy reference stepper, so this is the all-in price of `--trace-out`,
    not just span bookkeeping.
  * obs/recorder_depth{N} — capture throughput at ring depth N with the
    retained/dropped accounting and the resident-sample footprint, the
    memory axis of `FlightRecorder(max_windows=...)`.
"""
import tempfile

import numpy as np

from repro import obs
from repro.experiments.grid import GRIDS
from repro.experiments.sweep import run_sweep

from benchmarks.common import emit, timed

SPAN_BATCH = 2_000


def _span_batch():
    for _ in range(SPAN_BATCH):
        with obs.span("bench.nop", cat="bench"):
            pass


def _span_rows():
    tracer = obs.get_tracer()
    obs.disable_tracing()
    _, us_off = timed(_span_batch)
    obs.enable_tracing()
    tracer.reset()
    _, us_on = timed(_span_batch)
    obs.disable_tracing()
    tracer.reset()
    emit("obs/span_disabled", us_off / SPAN_BATCH,
         f"per_span_ns={us_off / SPAN_BATCH * 1e3:.0f}")
    emit("obs/span_enabled", us_on / SPAN_BATCH,
         f"per_span_ns={us_on / SPAN_BATCH * 1e3:.0f};"
         f"vs_disabled={us_on / max(us_off, 1e-9):.2f}x")


def _sweep_rows():
    cache = tempfile.mkdtemp(prefix="bench_obs_")
    grid = GRIDS["mini"]
    run_sweep(grid, cache_dir=cache)  # warm the content-hash cache
    _, us_plain = timed(run_sweep, grid, cache_dir=cache)

    tracer = obs.get_tracer()
    obs.enable_tracing()

    def traced():
        tracer.reset()
        return run_sweep(grid, cache_dir=cache, recorder=obs.FlightRecorder())

    _, us_traced = timed(traced)
    obs.disable_tracing()
    tracer.reset()
    overhead = (us_traced / max(us_plain, 1e-9) - 1.0) * 100.0
    emit("obs/sweep_plain", us_plain, f"ms={us_plain / 1e3:.1f}")
    emit("obs/sweep_traced", us_traced,
         f"ms={us_traced / 1e3:.1f};overhead_pct={overhead:.1f}")


class _Sched:
    """The attributes `FlightRecorder.capture_batch` reads."""

    def __init__(self, num_links, num_windows, window_s=1e-6):
        self.window_s = window_s
        self.num_links = num_links
        share = np.zeros((num_windows, 3))
        share[:, 0] = 1.0
        self.window_share = share


def _recorder_rows():
    total, chunk, links, configs = 4_096, 256, 16, 4
    serviced = np.random.default_rng(0).random((chunk, configs, links))
    backlog = serviced * 0.5
    scheds = [_Sched(links, chunk) for _ in range(configs)]
    for depth in (128, 512, 2_048):
        def capture(depth=depth):
            rec = obs.FlightRecorder(max_windows=depth)
            for start in range(0, total, chunk):
                rec.capture_batch(scheds, serviced, backlog, start_window=start)
            return rec

        rec, us = timed(capture)
        summ = rec.summary()
        retained = sum(t["windows_retained"] for t in summ["tracks"])
        # resident samples: retained windows × links × (util + backlog) floats
        approx_kb = retained * links * 2 * 8 / 1024.0
        emit(
            f"obs/recorder_depth{depth}",
            us / total,
            f"windows={total};retained={retained};"
            f"dropped={summ['dropped_windows']};approx_kb={approx_kb:.0f}",
        )


def run():
    _span_rows()
    _sweep_rows()
    _recorder_rows()
