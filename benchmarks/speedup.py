"""Fig. 7: execution-time speedup of the proposed mapping, 2-D mesh and
flattened butterfly, per algorithm per workload."""
from repro.core.mapping import map_graph
from repro.core.noc import FlattenedButterfly, Mesh2D
from repro.core.placement import auto_mesh_for_parts

from benchmarks.common import ALGS, emit, timed, traced, workloads

PARTS = 16


def _topos():
    m = auto_mesh_for_parts(PARTS)
    return {"mesh2d": m, "fbutterfly": FlattenedButterfly(m.kx, m.ky)}


def run():
    for gname in workloads():
        for alg in ALGS:
            g, tr = traced(gname, alg)
            for tname, topo in _topos().items():
                def compare_once():
                    opt = map_graph(
                        g.src, g.dst, g.num_nodes, PARTS, topology=topo,
                        edge_activity=tr.edge_activity,
                    )
                    base = map_graph(
                        g.src, g.dst, g.num_nodes, PARTS, topology=topo,
                        partitioner="random", placement_method="random",
                        edge_activity=tr.edge_activity,
                    )
                    return opt.compare_to(base, num_iterations=tr.num_iterations)

                res, us = timed(compare_once, repeats=1)
                emit(
                    f"fig7_speedup/{gname}/{alg}/{tname}", us,
                    f"speedup={res['speedup']:.2f}x;hop_decrease={res['hop_decrease']:.2f}x",
                )
