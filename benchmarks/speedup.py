"""Fig. 7: execution-time speedup of the proposed mapping, 2-D mesh and
flattened butterfly, per algorithm per workload.
Thin adapter over the shared sweep's proposed-vs-baseline comparisons."""
from repro.experiments.sweep import figure_comparisons

from benchmarks.common import emit, paper_sweep


def run():
    sweep = paper_sweep()
    for c in figure_comparisons(sweep.records):
        emit(
            f"fig7_speedup/{c['workload']}/{c['algorithm']}/{c['topology']}",
            c["elapsed_us"],
            f"speedup={c['speedup']:.2f}x;hop_decrease={c['hop_decrease']:.2f}x",
        )
