"""§Contention rows: the windowed NoC simulator (repro.nocsim) vs the
analytic serialization term, per scheme and routing arm, on the shared
paper-grid sweep inputs.  Rows report the contended/analytic network-time
excess (hotspot formation the aggregate peak misses), the contended
baseline-vs-proposed win per routing arm, and the stacked-stepper timing
(numpy reference vs the one-scan jax program).

Provenance note: the timed cell rebuilds its placement through the serial
`core.placement.place` reference, while `artifacts/sweeps/contention.json`
records the batched `place_batch` search's placements — the two converge to
local optima of the same neighbourhood and usually coincide, but these CSV
rows stand on their own timing/metrics and are not asserted equal to the
committed artifact's numbers."""
from repro.experiments.grid import GRIDS
from repro.nocsim import NocSimParams, contended_batch

from benchmarks.common import CACHE_DIR, PARTS, SCALE, emit, timed, traced, workloads


def _inputs():
    """One (traffic, baseline placement, proposed placement) cell: amazon ×
    pagerank × mesh2d at the benchmark scale — built through the same sweep
    machinery as the figure rows."""
    import dataclasses

    from repro.experiments.sweep import run_sweep

    grid = dataclasses.replace(
        GRIDS["contention"],
        workloads=("amazon",),
        algorithms=("pagerank",),
        topologies=("mesh2d",),
        parts=(PARTS,),
        scale=SCALE,
        contention=False,  # the rows below drive nocsim directly, timed
    )
    sweep = run_sweep(
        grid, cache_dir=CACHE_DIR, measure_serial=False, graphs=workloads(SCALE)
    )
    return sweep


def run():
    sweep = _inputs()
    by_scheme = {}
    for rec, cfg in ((r, r.config) for r in sweep.records):
        by_scheme["baseline" if cfg.is_baseline else "proposed"] = rec
    base, prop = by_scheme["baseline"], by_scheme["proposed"]

    # Rebuild the evaluated traffic/placements through the cache-backed
    # pipeline pieces the sweep already exercised (cheap at bench scale).
    from repro.core.placement import auto_mesh_for_parts, place
    from repro.experiments.cache import SweepCache

    cache = SweepCache(CACHE_DIR)
    g = workloads(SCALE)["amazon"]
    _, tr = traced("amazon", "pagerank", SCALE)
    cells = {}
    for rec in (base, prop):
        cfg = rec.config
        part = cache.partition(g, cfg.partitioner, cfg.num_parts)
        traffic = cache.traffic(g, part, tr)
        topo = auto_mesh_for_parts(cfg.num_parts, cfg.topology)
        pl = place(traffic, part, topo, method=cfg.placement, seed=cfg.seed)
        cells[("baseline" if cfg.is_baseline else "proposed")] = (traffic, pl, rec)

    for routing in ("dor", "adaptive2"):
        params = NocSimParams(routing=routing)
        results = {}
        for scheme, (traffic, pl, rec) in cells.items():
            (res,), us = timed(
                contended_batch,
                [traffic],
                [pl],
                noc_params=params,
                num_iterations=rec.num_iterations,
                backend="numpy",
            )
            results[scheme] = res
            emit(
                f"contention/{scheme}/{routing}",
                us,
                f"excess={res.contention_excess:.3f}x;"
                f"t_contended_s={res.t_network_contended_s:.3e};"
                f"p99_s={res.p99_latency_s:.3e}",
            )
        win = (
            results["baseline"].t_network_contended_s
            / results["proposed"].t_network_contended_s
        )
        emit(f"contention/win/{routing}", 0.0, f"contended_win={win:.2f}x")

    # closed-loop credit arm (repro.nocsim.credit): win retention per buffer
    # depth, the infinite-credit == open-loop identity, and the credit
    # stepper's own timing next to the open rows above.
    open_results = {}
    for scheme, (traffic, pl, rec) in cells.items():
        open_results[scheme] = contended_batch(
            [traffic], [pl], noc_params=NocSimParams(routing="dor"),
            num_iterations=rec.num_iterations, backend="numpy",
        )[0]
    open_win = (
        open_results["baseline"].t_network_contended_s
        / open_results["proposed"].t_network_contended_s
    )
    for depth in (0.5, 1.0, 4.0):
        params = NocSimParams(
            routing="dor", flow_control="credit", buffer_depth=depth
        )
        results = {}
        for scheme, (traffic, pl, rec) in cells.items():
            (res,), us = timed(
                contended_batch,
                [traffic],
                [pl],
                noc_params=params,
                num_iterations=rec.num_iterations,
                backend="numpy",
            )
            results[scheme] = res
            emit(
                f"contention/credit/{scheme}/d{depth:g}",
                us,
                f"t_contended_s={res.t_network_contended_s:.3e};"
                f"p99_s={res.p99_latency_s:.3e}",
            )
        win = (
            results["baseline"].t_network_contended_s
            / results["proposed"].t_network_contended_s
        )
        emit(
            f"contention/credit/win/d{depth:g}",
            0.0,
            f"contended_win={win:.2f}x;retained={win / open_win:.3f}",
        )
    inf_params = NocSimParams(
        routing="dor", flow_control="credit", buffer_depth=float("inf")
    )
    inf_max = 0.0
    for scheme, (traffic, pl, rec) in cells.items():
        res = contended_batch(
            [traffic], [pl], noc_params=inf_params,
            num_iterations=rec.num_iterations, backend="numpy",
        )[0]
        inf_max = max(
            inf_max,
            abs(res.t_network_contended_s - open_results[scheme].t_network_contended_s),
        )
    emit("contention/credit/inf_identity", 0.0, f"max_abs_vs_open={inf_max:g}")

    # backend timing parity row: the stacked jax scan vs the numpy loop over
    # BOTH schemes at once (the sweep-shaped call pattern).
    traffics = [cells["baseline"][0], cells["proposed"][0]]
    placements = [cells["baseline"][1], cells["proposed"][1]]
    params = NocSimParams()
    res_np, us_np = timed(
        contended_batch, traffics, placements, noc_params=params, backend="numpy"
    )
    try:
        res_jx, us_jx = timed(
            contended_batch, traffics, placements, noc_params=params, backend="jax"
        )
        parity = max(
            abs(a.t_network_contended_s - b.t_network_contended_s)
            / max(abs(a.t_network_contended_s), 1e-300)
            for a, b in zip(res_np, res_jx)
        )
        emit(
            "contention/backend/jax_scan",
            us_jx,
            f"numpy_us={us_np:.1f};parity_max_rel={parity:.2e}",
        )
        cparams = NocSimParams(flow_control="credit", buffer_depth=1.0)
        cres_np, cus_np = timed(
            contended_batch, traffics, placements, noc_params=cparams, backend="numpy"
        )
        cres_jx, cus_jx = timed(
            contended_batch, traffics, placements, noc_params=cparams, backend="jax"
        )
        cparity = max(
            abs(a.t_network_contended_s - b.t_network_contended_s)
            / max(abs(a.t_network_contended_s), 1e-300)
            for a, b in zip(cres_np, cres_jx)
        )
        emit(
            "contention/backend/credit_jax_scan",
            cus_jx,
            f"numpy_us={cus_np:.1f};parity_max_rel={cparity:.2e}",
        )
    except ImportError:
        emit("contention/backend/jax_scan", 0.0, f"numpy_us={us_np:.1f};jax=absent")
