"""Fig. 3: on-chip data movement per phase, normalised by graph size.
Thin adapter: phase bytes come from the shared sweep's per-config records
(traffic is partition-dependent but placement/topology-independent, so one
record per (workload, algorithm) under the proposed scheme is the figure)."""
from benchmarks.common import emit, paper_sweep


def run():
    sweep = paper_sweep()
    seen = set()
    for r in sweep.records:
        c = r.config
        if c.is_baseline or (c.workload, c.algorithm) in seen:
            continue
        seen.add((c.workload, c.algorithm))
        norm = r.phase_norm
        emit(
            f"fig3_movement/{c.workload}/{c.algorithm}", r.elapsed_us,
            f"process={norm['process']:.2f};reduce={norm['reduce']:.2f};"
            f"apply={norm['apply']:.3f};iters={r.num_iterations}",
        )
