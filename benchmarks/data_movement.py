"""Fig. 3: on-chip data movement per phase, normalised by graph size."""
from repro.core.partition import powerlaw_partition
from repro.core.traffic import traffic_from_partition

from benchmarks.common import ALGS, emit, timed, traced, workloads


def run():
    for gname in workloads():
        for alg in ALGS:
            g, tr = traced(gname, alg)
            p = powerlaw_partition(g.src, g.dst, g.num_nodes, 16)
            t, us = timed(
                traffic_from_partition, p, g.src, g.dst, edge_activity=tr.edge_activity
            )
            graph_bytes = (g.num_edges * 2 + g.num_nodes) * 8  # ET + props @ 8B words
            norm = t.normalized_by(graph_bytes)
            emit(
                f"fig3_movement/{gname}/{alg}", us,
                f"process={norm['process']:.2f};reduce={norm['reduce']:.2f};"
                f"apply={norm['apply']:.3f};iters={tr.num_iterations}",
            )
