"""Kernel-layer microbenchmarks (ops-level, CPU ref path): wall time per call
+ achieved bytes — the per-kernel harness the TPU run would use as-is."""
import jax
import jax.numpy as jnp

from repro.graph.generators import rmat
from repro.graph.structs import build_ell
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.segment_spmm.ops import segment_spmm

from benchmarks.common import emit, timed


def run():
    key = jax.random.key(0)
    # flash attention (blocked ref path — the production CPU fallback)
    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="ref"))
    _, us = timed(lambda: fn(q, k, v).block_until_ready())
    flops = 4 * 1024 * 1024 * 8 * 64 / 2  # causal
    emit("kernel/flash_attention_1k", us, f"gflops_per_s={flops / us / 1e3:.1f}")

    # segment spmm over the power-law ELL
    g = rmat(4000, 60_000, seed=0)
    ell = build_ell(g.reversed())
    x = jax.random.normal(key, (4000, 128), jnp.float32)
    fn2 = jax.jit(lambda x: segment_spmm(x, ell, impl="ref"))
    _, us = timed(lambda: fn2(x).block_until_ready())
    gbytes = 60_000 * 128 * 4 / 1e9
    emit("kernel/segment_spmm_60k", us, f"fill={ell.fill_fraction():.2f};"
         f"gather_GBps={gbytes / (us / 1e6):.1f}")

    # embedding bag
    tables = jax.random.normal(key, (26, 100_000, 16), jnp.float32)
    ids = jax.random.randint(key, (4096, 26, 1), 0, 100_000)
    fn3 = jax.jit(lambda t, i: embedding_bag(t, i, impl="ref"))
    _, us = timed(lambda: fn3(tables, ids).block_until_ready())
    emit("kernel/embedding_bag_4k", us,
         f"lookups_per_s={4096 * 26 / (us / 1e6):.0f}")
