"""Placement-search benchmark: the serial per-config `place` loop (greedy/
quad + random-probe two_opt, Algorithms 3–4) vs the batched swap-delta engine
(`repro.experiments.placement_batch`) on paper-grid-shaped inputs.

Rows (name,us_per_call,derived):
  placement/serial_loop              the replaced one-config-at-a-time search
  placement/batched_numpy            stacked steepest descent, float64 BLAS
  placement/batched_jax              same program under jax.jit + while_loop
  placement/greedy_construct_serial  per-config greedy_placement loop
  placement/greedy_construct_batched_{numpy,jax}
                                     stacked argmax-insertion construction
  placement/torus_construct_serial   per-config torus_quad_placement loop
  placement/torus_construct_batched_{numpy,jax}
                                     stacked wrap-aware layout assembly
  placement/torus_greedy2opt_search  the greedy+2-opt search the torus
                                     construction replaces (same configs)
Derived fields carry the speedup vs the matching serial loop, the max H
ratio (batched/serial weighted hops — must stay ≤ 1.0 + fp noise for the
search rows; constructive/searched for the torus rows, where ≤ 1.0 means
the construction beats the search it skips) and, for the numpy
construction rows, the bit-parity flag.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CACHE_DIR, PARTS, SCALE, emit, timed, workloads
from repro.core.placement import (
    auto_mesh_for_parts,
    greedy_placement,
    place,
    torus_quad_placement,
)
from repro.experiments.cache import SweepCache
from repro.experiments.grid import GRIDS
from repro.experiments.placement_batch import (
    greedy_construct_batch,
    place_batch,
    torus_construct_batch,
)
from repro.experiments.sweep import DEFAULT_TRACE_ITERS, TRACE_ITERS


def _paper_inputs():
    """(traffics, partitions, topologies, methods, seeds) for the searched
    half of the paper grid (the proposed-scheme configs; the baseline half is
    a constructive random layout with nothing to search)."""
    grid = GRIDS["paper"]
    cache = SweepCache(CACHE_DIR)
    graphs = workloads(SCALE)
    parts_memo: dict[tuple, object] = {}
    traffics, partitions, topologies, methods, seeds = [], [], [], [], []
    for c in grid.expand():
        if c.is_baseline:
            continue
        g = graphs[c.workload]
        tr = cache.trace(
            g, c.algorithm, max_iterations=TRACE_ITERS.get(c.algorithm, DEFAULT_TRACE_ITERS)
        )
        pkey = (c.workload, c.partitioner)
        part = parts_memo.get(pkey)
        if part is None:
            part = parts_memo[pkey] = cache.partition(g, c.partitioner, PARTS)
        traffics.append(cache.traffic(g, part, tr))
        partitions.append(part)
        topologies.append(auto_mesh_for_parts(PARTS, c.topology))
        # benchmark the search, not HiGHS: pin tiny instances to quad
        methods.append("quad" if PARTS <= 4 else c.placement)
        seeds.append(c.seed)
    return traffics, partitions, topologies, methods, seeds


def run() -> None:
    traffics, partitions, topologies, methods, seeds = _paper_inputs()
    n_cfg = len(traffics)

    def serial():
        return [
            place(t, p, topo, method=m, seed=s)
            for t, p, topo, m, s in zip(traffics, partitions, topologies, methods, seeds)
        ]

    serial_pls, us_serial = timed(serial, repeats=3)
    emit("placement/serial_loop", us_serial, f"configs={n_cfg}")
    h_serial = np.array(
        [pl.weighted_hops(t.bytes_matrix) for pl, t in zip(serial_pls, traffics)]
    )

    for backend in ("numpy", "jax"):
        if backend == "jax":
            try:
                import jax  # noqa: F401
            except ImportError:
                continue
        (pls, stats), us = timed(
            place_batch,
            traffics,
            partitions,
            topologies,
            methods=methods,
            seeds=seeds,
            backend=backend,
            repeats=3,
        )
        h = np.array([pl.weighted_hops(t.bytes_matrix) for pl, t in zip(pls, traffics)])
        ratio = float((h / np.maximum(h_serial, 1e-12)).max())
        emit(
            f"placement/batched_{backend}",
            us,
            f"speedup={us_serial / max(us, 1e-9):.2f}x;h_max_ratio={ratio:.4f}"
            f";steps={stats.steps}",
        )

    # ---- greedy construction in isolation (the tentpole stacked path) ------
    ws = [t.bytes_matrix for t in traffics]

    def construct_serial():
        return [
            greedy_placement(w, topo, seed=s).site
            for w, topo, s in zip(ws, topologies, seeds)
        ]

    serial_sites, us_cons = timed(construct_serial, repeats=3)
    emit("placement/greedy_construct_serial", us_cons, f"configs={n_cfg}")
    for backend in ("numpy", "jax"):
        if backend == "jax":
            try:
                import jax  # noqa: F401
            except ImportError:
                continue
        (sites, _), us = timed(
            greedy_construct_batch, ws, topologies, seeds=seeds, backend=backend, repeats=3
        )
        derived = f"speedup={us_cons / max(us, 1e-9):.2f}x"
        if backend == "numpy":  # the batched numpy constructor is bit-exact
            parity = all(np.array_equal(a, b) for a, b in zip(serial_sites, sites))
            derived += f";bit_parity={parity}"
        emit(f"placement/greedy_construct_batched_{backend}", us, derived)

    # ---- torus-native constructive layouts (this PR's stacked path) --------
    torus_topo = auto_mesh_for_parts(PARTS, "torus2d")
    if (torus_topo.kx // 2) * (torus_topo.ky // 2) >= PARTS:  # quads fit
        _torus_rows(ws, traffics, partitions, seeds, torus_topo)


def _torus_rows(ws, traffics, partitions, seeds, torus_topo) -> None:
    """The placement/torus_* rows — skipped entirely (no rows) when 2×2
    quads don't fit the BENCH_PARTS auto torus."""
    n_cfg = len(ws)
    torus_topos = [torus_topo for _ in ws]

    def torus_serial():
        return [torus_quad_placement(PARTS, topo, w) for w, topo in zip(ws, torus_topos)]

    serial_tq, us_tq = timed(torus_serial, repeats=3)
    # The search the construction replaces, on the identical torus configs.
    (search_pls, _), us_search = timed(
        place_batch,
        traffics,
        partitions,
        torus_topos,
        methods="greedy",
        seeds=seeds,
        backend="numpy",
        repeats=1,
    )
    h_ratio = float(
        max(
            c.weighted_hops(w) / max(s.weighted_hops(w), 1e-12)
            for c, s, w in zip(serial_tq, search_pls, ws)
        )
    )
    emit(
        "placement/torus_greedy2opt_search",
        us_search,
        f"configs={n_cfg};h_constructive_over_searched_max={h_ratio:.4f}",
    )
    emit(
        "placement/torus_construct_serial",
        us_tq,
        f"configs={n_cfg};search_time_saving={us_search / max(us_tq, 1e-9):.0f}x",
    )
    for backend in ("numpy", "jax"):
        if backend == "jax":
            try:
                import jax  # noqa: F401
            except ImportError:
                continue
        (sites, _), us = timed(
            torus_construct_batch, ws, torus_topos, backend=backend, repeats=3
        )
        derived = f"speedup={us_tq / max(us, 1e-9):.2f}x"
        if backend == "numpy":  # the batched numpy constructor is bit-exact
            parity = all(
                np.array_equal(pl.site, s) for pl, s in zip(serial_tq, sites)
            )
            derived += f";bit_parity={parity}"
        emit(f"placement/torus_construct_batched_{backend}", us, derived)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
