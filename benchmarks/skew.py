"""Fig. 4: power-law skew of the Table-2 workloads (n(d) ∝ 1/d^α)."""
from repro.core.degree import out_degrees, skew_stats

from benchmarks.common import emit, timed, workloads


def run():
    for name, g in workloads().items():
        deg = out_degrees(g.src, g.num_nodes)
        stats, us = timed(skew_stats, deg)
        emit(
            f"fig4_skew/{name}", us,
            f"alpha={stats.alpha:.2f};frac_v_for_90pct_e="
            f"{stats.frac_vertices_for_90pct_edges:.3f};is_power_law={stats.is_power_law}",
        )
