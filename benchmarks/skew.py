"""Fig. 4: power-law skew of the Table-2 workloads (n(d) ∝ 1/d^α).
Thin adapter over `repro.experiments.sweep.workload_stats`."""
from benchmarks.common import emit, timed, workload_stats, workloads


def run():
    for name, g in workloads().items():
        stats, us = timed(workload_stats, name, g)
        emit(
            f"fig4_skew/{name}", us,
            f"alpha={stats['alpha']:.2f};frac_v_for_90pct_e="
            f"{stats['frac_vertices_for_90pct_edges']:.3f};"
            f"is_power_law={stats['is_power_law']}",
        )
