"""Fig. 5: average hop count — proposed placement vs randomized baseline."""
from repro.core.mapping import map_graph

from benchmarks.common import emit, timed, traced, workloads


def run():
    for gname in workloads():
        g, tr = traced(gname, "pagerank")
        opt, us = timed(
            map_graph, g.src, g.dst, g.num_nodes, 16,
            edge_activity=tr.edge_activity, repeats=1,
        )
        base = map_graph(
            g.src, g.dst, g.num_nodes, 16, partitioner="random",
            placement_method="random", edge_activity=tr.edge_activity,
        )
        h_opt = opt.placement.average_hops(opt.traffic.bytes_matrix)
        h_base = base.placement.average_hops(base.traffic.bytes_matrix)
        emit(
            f"fig5_hops/{gname}", us,
            f"hops_proposed={h_opt:.2f};hops_random={h_base:.2f};"
            f"decrease={h_base / max(h_opt, 1e-9):.2f}x",
        )
