"""Fig. 5: average hop count — proposed placement vs randomized baseline.
Thin adapter over the shared sweep's proposed-vs-baseline comparisons."""
from repro.experiments.sweep import figure_comparisons

from benchmarks.common import emit, paper_sweep


def run():
    sweep = paper_sweep()
    for c in figure_comparisons(sweep.records):
        if c["algorithm"] != "pagerank" or c["topology"] != "mesh2d":
            continue
        emit(
            f"fig5_hops/{c['workload']}", c["elapsed_us"],
            f"hops_proposed={c['avg_hops_optimized']:.2f};"
            f"hops_random={c['avg_hops_baseline']:.2f};"
            f"decrease={c['hop_decrease']:.2f}x",
        )
