"""Benchmark runner — one section per paper figure/table.
Prints ``name,us_per_call,derived`` CSV (assignment contract)."""
import sys


def main() -> None:
    from benchmarks import data_movement, energy, hop_count, kernels_bench, skew, speedup

    print("name,us_per_call,derived")
    for mod in (skew, data_movement, hop_count, speedup, energy, kernels_bench):
        mod.run()


if __name__ == "__main__":
    main()
