"""Benchmark runner — one section per paper figure/table.
Prints ``name,us_per_call,derived`` CSV (assignment contract).

    PYTHONPATH=src python benchmarks/run.py                 # every section
    PYTHONPATH=src python benchmarks/run.py --only skew,hop_count

Figure sections share one batched sweep of the paper grid
(`repro.experiments`); `BENCH_SCALE`/`BENCH_PARTS`/`BENCH_CACHE` shrink it
for smoke tests (see benchmarks/common.py).
"""
import argparse
import os
import sys

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path; the repo
# root (one level up) is what makes `benchmarks.*` importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

MODULES = (
    "skew",
    "data_movement",
    "hop_count",
    "placement",
    "speedup",
    "energy",
    "contention",
    "kernels_bench",
    "obs",
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--only",
        default=None,
        help=f"comma-separated subset of sections to run (options: {','.join(MODULES)})",
    )
    args = ap.parse_args(argv)
    selected = MODULES if args.only is None else tuple(args.only.split(","))
    unknown = set(selected) - set(MODULES)
    if unknown:
        ap.error(f"unknown sections: {sorted(unknown)}; options: {','.join(MODULES)}")

    import importlib

    print("name,us_per_call,derived")
    for name in selected:
        importlib.import_module(f"benchmarks.{name}").run()


if __name__ == "__main__":
    main()
