"""Shared benchmark harness: Table-2 workloads (scaled), traced algorithm
executions, and the CSV reporting contract (name,us_per_call,derived)."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.graph.algorithms import bfs_program, pagerank_program, prepare_graph, sssp_program
from repro.graph.generators import table2_workloads
from repro.graph.vertex_program import run_traced

# Offline container: Table 2 graphs are regenerated as RMAT at `SCALE` of the
# published |V|/|E| (DESIGN.md §2) — the skew (Fig. 4) is preserved, which is
# what every downstream figure depends on.
SCALE = 0.01

ALGS = {
    "bfs": bfs_program,
    "sssp": sssp_program,
    "pagerank": pagerank_program,
}


@functools.lru_cache(maxsize=None)
def workloads(scale: float = SCALE):
    return table2_workloads(scale=scale)


@functools.lru_cache(maxsize=None)
def traced(graph_name: str, alg: str, scale: float = SCALE):
    g = workloads(scale)[graph_name]
    g = prepare_graph(alg, g)
    max_it = 40 if alg == "pagerank" else 200
    return g, run_traced(g, ALGS[alg](), source=0, max_iterations=max_it)


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
