"""Shared benchmark harness — thin front-end over `repro.experiments`.

The figure benchmarks (fig3/5/7/8) are adapters over ONE shared sweep of the
paper grid (`repro.experiments.sweep.run_sweep`): traces are content-hash
cached on disk and all configurations are evaluated in a single batched
`simulate_batch` call, instead of the per-config Python loops this module
used to drive.  The CSV reporting contract (`name,us_per_call,derived`) is
unchanged.

Environment knobs (used by the smoke tests and CI):
  BENCH_SCALE  workload scale (default 0.01 of published Table-2 sizes)
  BENCH_PARTS  engines per config (default 16, the paper's setting)
  BENCH_CACHE  sweep cache dir (default artifacts/sweep_cache; "" disables)
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time

from repro.experiments.grid import GRIDS
from repro.experiments.sweep import run_sweep, workload_stats
from repro.graph.generators import table2_workloads

# Offline container: Table 2 graphs are regenerated as RMAT at `SCALE` of the
# published |V|/|E| — the skew (Fig. 4) is preserved, which is what every
# downstream figure depends on (EXPERIMENTS.md §Calibration).
SCALE = float(os.environ.get("BENCH_SCALE", "0.01"))
PARTS = int(os.environ.get("BENCH_PARTS", "16"))
CACHE_DIR = os.environ.get("BENCH_CACHE", "artifacts/sweep_cache") or None

ALG_NAMES = ("bfs", "sssp", "pagerank")


@functools.lru_cache(maxsize=None)
def _workloads(scale: float):
    return table2_workloads(scale=scale)


def workloads(scale: float | None = None):
    # Normalised before the lru_cache so workloads() and workloads(SCALE)
    # share one entry (and one set of generated graphs).
    return _workloads(SCALE if scale is None else scale)


@functools.lru_cache(maxsize=None)
def paper_sweep(scale: float | None = None, parts: int | None = None):
    """The one sweep behind fig3/5/7/8 — run once, shared by every module."""
    scale = SCALE if scale is None else scale
    parts = PARTS if parts is None else parts
    grid = dataclasses.replace(
        GRIDS["paper"],
        scale=scale,
        parts=(parts,),
        # "auto" placement solves tiny instances (≤4 parts) with the exact
        # MILP — right for tests of optimality, wrong for a timed benchmark.
        placements=("auto" if parts > 4 else "quad", "random"),
    )
    return run_sweep(
        grid, cache_dir=CACHE_DIR, measure_serial=False, graphs=workloads(scale)
    )


@functools.lru_cache(maxsize=None)
def traced(graph_name: str, alg: str, scale: float | None = None):
    """(prepared graph, TraceResult) through the content-hash sweep cache."""
    from repro.experiments.cache import SweepCache
    from repro.experiments.sweep import DEFAULT_TRACE_ITERS, TRACE_ITERS
    from repro.graph.algorithms import prepare_graph

    g = workloads(scale)[graph_name]
    cache = SweepCache(CACHE_DIR)
    tr = cache.trace(g, alg, max_iterations=TRACE_ITERS.get(alg, DEFAULT_TRACE_ITERS))
    return prepare_graph(alg, g), tr


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


__all__ = [
    "SCALE",
    "PARTS",
    "CACHE_DIR",
    "ALG_NAMES",
    "workloads",
    "workload_stats",
    "paper_sweep",
    "traced",
    "timed",
    "emit",
]
